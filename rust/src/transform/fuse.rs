//! Loop fusion: merge two adjacent sibling nests with identical bounds.
//!
//! `for i { A } for i' { B }` becomes `for i { A; B }` when `i` and
//! `i'` share `[lb, ub)`. Fusion makes iteration `B(i)` run before
//! `A(i+1), A(i+2), …` that originally preceded it, so legality is per
//! conflicting access pair across the nests: with the second nest's
//! iterator identified with the first's, the *raw* (un-normalized)
//! fused-level distance `d` (meaning `iter_B = iter_A + d` on the
//! aliasing cell) must satisfy `d >= 0` — the producing `A` iteration
//! still precedes the consuming `B` iteration after fusion. A non-zero
//! constant component at an outer shared level orders the pair
//! identically in both programs and ends the check early; an `Any`
//! component refuses.

use crate::ir::{Access, AffineExpr, Kernel, Loop, LoopId, Node};
use crate::poly::deps::{access_pair_components, DepKind, DirComp, DirVector};

use super::legality::LegalityCert;
use super::rebuild::{find_loop, rebuild, splice, substitute};

/// The rule string recorded in fusion certificates.
pub const RULE: &str = "fuse: raw fused-level distance is non-negative for every conflicting pair";

/// The fusion criterion for one raw pair vector (entries outermost
/// first, ending at the fused level).
fn pair_legal(comps: &[(LoopId, DirComp)], fused: LoopId) -> bool {
    for &(l, c) in comps {
        if l == fused {
            return matches!(c, DirComp::Dist(d) if d >= 0);
        }
        match c {
            DirComp::Dist(0) => continue,
            // a non-`=` outer level orders the pair identically in both
            // programs: fusion only reorders within enclosing iterations
            DirComp::Dist(_) | DirComp::Pos => return true,
            DirComp::Any => return false,
        }
    }
    false // fused level missing from the shared nest: conservative refuse
}

/// Certify and apply: fuse adjacent sibling `second` into `first`.
pub fn apply(k: &Kernel, first: LoopId, second: LoopId) -> Result<(Kernel, LegalityCert), String> {
    if first == second {
        return Err("cannot fuse a loop with itself".into());
    }
    let m1 = k.loop_meta(first);
    let m2 = k.loop_meta(second);
    if m1.parent != m2.parent {
        return Err(format!(
            "{} and {} are not siblings",
            k.loop_name(first),
            k.loop_name(second)
        ));
    }
    let siblings: &[Node] = match m1.parent {
        Some(p) => &find_loop(&k.roots, p).expect("parent exists").body,
        None => &k.roots,
    };
    let pos_of = |id: LoopId| {
        siblings
            .iter()
            .position(|n| matches!(n, Node::Loop(l) if l.id == id))
    };
    let (p1, p2) = match (pos_of(first), pos_of(second)) {
        (Some(a), Some(b)) => (a, b),
        _ => return Err("loop not found among its siblings".into()),
    };
    if p2 != p1 + 1 {
        return Err(format!(
            "{} does not immediately follow {}",
            k.loop_name(second),
            k.loop_name(first)
        ));
    }
    let (Node::Loop(l1), Node::Loop(l2)) = (&siblings[p1], &siblings[p2]) else {
        unreachable!("positions matched Loop nodes")
    };
    if l1.lb != l2.lb || l1.ub != l2.ub {
        return Err(format!(
            "bounds of {} and {} differ",
            k.loop_name(first),
            k.loop_name(second)
        ));
    }

    // Legality: raw pair vectors over the shared nest *after*
    // identifying `second`'s iterator with `first`'s. Normalized
    // whole-kernel vectors erase which nest ran first, so the check
    // derives orientation-preserving components directly.
    let shared = k.loop_path(first);
    let subst = |e: &AffineExpr| -> AffineExpr {
        let mut out = AffineExpr::constant(e.constant);
        for &(l, c) in &e.terms {
            out.add_term(if l == second { first } else { l }, c);
        }
        out
    };
    let mut checked = Vec::new();
    for &sa in &m1.stmts {
        for &sb in &m2.stmts {
            for (aa, wa) in k.stmt_accesses(sa) {
                for (ab, wb) in k.stmt_accesses(sb) {
                    if aa.array != ab.array || (!wa && !wb) {
                        continue;
                    }
                    let ab2 = Access::new(ab.array, ab.indices.iter().map(&subst).collect());
                    let comps = access_pair_components(aa, &ab2, &shared);
                    if !pair_legal(&comps, first) {
                        return Err(format!(
                            "dependence on {} between {sa} and {sb} reverses under fusion",
                            k.array(aa.array).name
                        ));
                    }
                    let kind = match (wa, wb) {
                        (true, true) => DepKind::Waw,
                        (true, false) => DepKind::Raw,
                        _ => DepKind::War,
                    };
                    checked.push(DirVector {
                        kind,
                        src: sa,
                        dst: sb,
                        array: aa.array,
                        entries: comps,
                    });
                }
            }
        }
    }
    let cert = LegalityCert {
        rule: RULE,
        checked,
    };

    let mut body = l1.body.clone();
    body.extend(l2.body.iter().map(|n| substitute(n, second, first)));
    let fused = Node::Loop(Loop {
        id: l1.id,
        name: l1.name.clone(),
        lb: l1.lb.clone(),
        ub: l1.ub.clone(),
        body,
    });
    let (roots, hit) = splice(&k.roots, first, &[fused]);
    debug_assert!(hit);
    let (roots, hit) = splice(&roots, second, &[]);
    debug_assert!(hit);
    Ok((rebuild(&k.name, k.dtype, k.arrays.clone(), &roots), cert))
}
