//! Branch-and-bound budget allocation over per-kernel fronts.
//!
//! One front point must be chosen per kernel; the objective is total
//! system throughput (sum of per-kernel GF/s) and the constraints are
//! the device's summed DSP / on-chip-byte / LUT budgets. The search is
//! a depth-first branch-and-bound in the solver's bound-ascending deal
//! spirit: each kernel's points are visited **best-throughput-first**,
//! and two admissible prunes cut subtrees —
//!
//! * **optimistic bound**: partial throughput + the sum of the
//!   remaining kernels' per-front *maximum* GF/s (each term bounds any
//!   completion, so the sum does);
//! * **feasibility bound**: partial usage + the sum of the remaining
//!   kernels' per-front *minimum* per-axis usage (no completion can use
//!   less, so exceeding the budget here is final).
//!
//! Both prunes carry a tiny relative slack so floating-point
//! re-association can never cut the true optimum; exact totals are
//! recomputed left-to-right at each leaf, and [`allocate_brute`]
//! enumerates the identical visit order with the identical
//! strict-improvement rule — so the two agree bit-for-bit, which
//! `tests/integration_system.rs` checks on random small instances.

use super::KernelFront;
use crate::hls::Device;

/// Guard band on both prunes: admissibility must survive f64
/// re-association between the incremental bound and the leaf total.
const SLACK: f64 = 1e-9;

/// One chosen point per kernel plus its exact totals.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    /// Per kernel (input order): index into that kernel's front.
    pub choice: Vec<usize>,
    /// Total system throughput, GF/s.
    pub gflops: f64,
    /// Summed DSP usage of the chosen points.
    pub dsp: f64,
    /// Summed on-chip bytes of the chosen points.
    pub onchip_bytes: f64,
    /// Summed LUT usage of the chosen points.
    pub lut: f64,
}

/// Search result: the best feasible allocation (if any) and how many
/// search nodes were expanded finding it.
#[derive(Clone, Debug)]
pub struct AllocOutcome {
    /// Best feasible allocation, `None` when no assignment fits the
    /// budget (or some kernel has an empty front).
    pub best: Option<Allocation>,
    /// Nodes expanded (b&b) or leaves enumerated (brute force).
    pub nodes: u64,
}

struct Budget {
    dsp: f64,
    onchip: f64,
    lut: f64,
}

impl Budget {
    fn of(dev: &Device) -> Budget {
        Budget {
            dsp: dev.dsp_total as f64,
            onchip: dev.onchip_bytes as f64,
            lut: dev.lut_total as f64,
        }
    }

    fn fits(&self, dsp: f64, onchip: f64, lut: f64) -> bool {
        dsp <= self.dsp && onchip <= self.onchip && lut <= self.lut
    }
}

/// Exact totals of a complete choice, summed left-to-right in kernel
/// input order — the one evaluation order both searches share, so their
/// f64 results are bit-identical.
fn totals(ks: &[KernelFront], choice: &[usize]) -> (f64, f64, f64, f64) {
    let (mut g, mut d, mut o, mut l) = (0.0, 0.0, 0.0, 0.0);
    for (k, &c) in ks.iter().zip(choice) {
        g += k.gflops[c];
        d += k.front[c].dsp;
        o += k.front[c].onchip_bytes;
        l += k.front[c].lut;
    }
    (g, d, o, l)
}

/// Per-kernel visit order: descending GF/s, ties by ascending front
/// index (the canonical-order point wins). `total_cmp` so a NaN
/// throughput — impossible from finite latencies, but cheap to be safe
/// about — sorts last instead of panicking.
fn visit_order(k: &KernelFront) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..k.front.len()).collect();
    idx.sort_by(|&x, &y| k.gflops[y].total_cmp(&k.gflops[x]).then(x.cmp(&y)));
    idx
}

struct Search<'a> {
    ks: &'a [KernelFront],
    order: Vec<Vec<usize>>,
    /// `suffix_gmax[i]` = Σ over kernels `i..` of their max point GF/s.
    suffix_gmax: Vec<f64>,
    /// Per-axis Σ over kernels `i..` of their min point usage.
    suffix_min: Vec<[f64; 3]>,
    budget: Budget,
    best: Option<Allocation>,
    best_g: f64,
    nodes: u64,
}

impl Search<'_> {
    fn dfs(&mut self, i: usize, choice: &mut Vec<usize>, used: [f64; 3], cur_g: f64) {
        self.nodes += 1;
        if i == self.ks.len() {
            let (g, d, o, l) = totals(self.ks, choice);
            if self.budget.fits(d, o, l) && g > self.best_g {
                self.best_g = g;
                self.best = Some(Allocation {
                    choice: choice.clone(),
                    gflops: g,
                    dsp: d,
                    onchip_bytes: o,
                    lut: l,
                });
            }
            return;
        }
        // feasibility prune: even the cheapest completion overflows
        let lb = [
            used[0] + self.suffix_min[i][0],
            used[1] + self.suffix_min[i][1],
            used[2] + self.suffix_min[i][2],
        ];
        if lb[0] > self.budget.dsp * (1.0 + SLACK)
            || lb[1] > self.budget.onchip * (1.0 + SLACK)
            || lb[2] > self.budget.lut * (1.0 + SLACK)
        {
            return;
        }
        // optimistic bound: no completion beats the incumbent
        let bound = cur_g + self.suffix_gmax[i];
        if bound + bound.abs() * SLACK <= self.best_g {
            return;
        }
        for oi in 0..self.order[i].len() {
            let pi = self.order[i][oi];
            let p = &self.ks[i].front[pi];
            choice.push(pi);
            self.dfs(
                i + 1,
                choice,
                [used[0] + p.dsp, used[1] + p.onchip_bytes, used[2] + p.lut],
                cur_g + self.ks[i].gflops[pi],
            );
            choice.pop();
        }
    }
}

fn suffixes(ks: &[KernelFront]) -> (Vec<f64>, Vec<[f64; 3]>) {
    let n = ks.len();
    let mut gmax = vec![0.0; n + 1];
    let mut rmin = vec![[0.0; 3]; n + 1];
    for i in (0..n).rev() {
        let g = ks[i].gflops.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        gmax[i] = g + gmax[i + 1];
        let axis = |f: fn(&crate::nlp::FrontPoint) -> f64| {
            ks[i].front.iter().map(f).fold(f64::INFINITY, f64::min)
        };
        rmin[i] = [
            axis(|p| p.dsp) + rmin[i + 1][0],
            axis(|p| p.onchip_bytes) + rmin[i + 1][1],
            axis(|p| p.lut) + rmin[i + 1][2],
        ];
    }
    (gmax, rmin)
}

/// Branch-and-bound allocation: the highest-throughput budget-feasible
/// choice of one front point per kernel, deterministic (first strict
/// improvement in DFS order wins ties). Returns `best: None` when some
/// kernel has an empty front or nothing fits.
pub fn allocate(ks: &[KernelFront], dev: &Device) -> AllocOutcome {
    if ks.is_empty() || ks.iter().any(|k| k.front.is_empty()) {
        return AllocOutcome {
            best: None,
            nodes: 0,
        };
    }
    let (suffix_gmax, suffix_min) = suffixes(ks);
    let mut s = Search {
        ks,
        order: ks.iter().map(visit_order).collect(),
        suffix_gmax,
        suffix_min,
        budget: Budget::of(dev),
        best: None,
        best_g: f64::NEG_INFINITY,
        nodes: 0,
    };
    s.dfs(0, &mut Vec::with_capacity(ks.len()), [0.0; 3], 0.0);
    AllocOutcome {
        best: s.best,
        nodes: s.nodes,
    }
}

/// Brute-force oracle: enumerate every complete choice in the exact
/// same visit order as [`allocate`]'s DFS, keep the first strict
/// improvement. Exponential — test/cross-check use only.
pub fn allocate_brute(ks: &[KernelFront], dev: &Device) -> AllocOutcome {
    if ks.is_empty() || ks.iter().any(|k| k.front.is_empty()) {
        return AllocOutcome {
            best: None,
            nodes: 0,
        };
    }
    let order: Vec<Vec<usize>> = ks.iter().map(visit_order).collect();
    let budget = Budget::of(dev);
    let mut best: Option<Allocation> = None;
    let mut best_g = f64::NEG_INFINITY;
    let mut nodes = 0u64;
    let mut odo = vec![0usize; ks.len()];
    loop {
        nodes += 1;
        let choice: Vec<usize> = odo.iter().enumerate().map(|(i, &o)| order[i][o]).collect();
        let (g, d, o, l) = totals(ks, &choice);
        if budget.fits(d, o, l) && g > best_g {
            best_g = g;
            best = Some(Allocation {
                choice,
                gflops: g,
                dsp: d,
                onchip_bytes: o,
                lut: l,
            });
        }
        // odometer increment, last kernel fastest (matches DFS order)
        let mut i = ks.len();
        loop {
            if i == 0 {
                return AllocOutcome { best, nodes };
            }
            i -= 1;
            odo[i] += 1;
            if odo[i] < order[i].len() {
                break;
            }
            odo[i] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nlp::FrontPoint;
    use crate::pragma::Design;
    use crate::util::rng::Rng;

    fn kf(name: &str, pts: &[(f64, f64, f64, f64)]) -> KernelFront {
        // synthetic fronts need no real kernel: an empty design suffices
        let k = crate::benchmarks::kernel_gemm(4, 4, 4, crate::ir::DType::F32);
        KernelFront {
            name: name.into(),
            front: pts
                .iter()
                .map(|&(_, dsp, onchip, lut)| FrontPoint {
                    design: Design::empty(&k),
                    latency: 1.0,
                    risk: 0.0,
                    dsp,
                    onchip_bytes: onchip,
                    lut,
                })
                .collect(),
            gflops: pts.iter().map(|p| p.0).collect(),
            lower_bound: 0.0,
            optimal: true,
            solve_time_s: 0.0,
            configs: 0,
        }
    }

    fn tiny_device(dsp: u64, onchip: u64, lut: u64) -> Device {
        let mut d = Device::u200();
        d.dsp_total = dsp;
        d.onchip_bytes = onchip;
        d.lut_total = lut;
        d
    }

    #[test]
    fn picks_the_best_feasible_combination() {
        // kernel A: fast point too big, small point fits
        let a = kf("a", &[(10.0, 80.0, 10.0, 10.0), (4.0, 20.0, 10.0, 10.0)]);
        let b = kf("b", &[(6.0, 60.0, 10.0, 10.0), (5.0, 30.0, 10.0, 10.0)]);
        let dev = tiny_device(100, 1000, 1000);
        let out = allocate(&[a, b], &dev);
        let best = out.best.expect("feasible");
        // a0+b0 = 140 dsp, a0+b1 = 110: over budget. a1+b0 = 80 dsp at
        // 10 GF/s beats a1+b1 = 50 dsp at 9 GF/s.
        assert_eq!(best.choice, vec![1, 0]);
        assert!((best.gflops - 10.0).abs() < 1e-12);
        assert!(best.dsp <= 100.0);
    }

    #[test]
    fn empty_front_or_overflow_yields_none() {
        let a = kf("a", &[]);
        let b = kf("b", &[(1.0, 5.0, 5.0, 5.0)]);
        let dev = tiny_device(100, 100, 100);
        assert!(allocate(&[a, b.clone()], &dev).best.is_none());
        let big = kf("big", &[(9.0, 500.0, 5.0, 5.0)]);
        assert!(allocate(&[big, b], &dev).best.is_none());
    }

    #[test]
    fn branch_and_bound_matches_brute_force_on_random_instances() {
        let mut rng = Rng::new(0xA110C);
        for case in 0..60u64 {
            let nk = 1 + (rng.next_u64() % 3) as usize;
            let ks: Vec<KernelFront> = (0..nk)
                .map(|i| {
                    let np = 1 + (rng.next_u64() % 8) as usize;
                    let pts: Vec<(f64, f64, f64, f64)> = (0..np)
                        .map(|_| {
                            let r = |rng: &mut Rng, m: u64| (rng.next_u64() % m) as f64;
                            (
                                1.0 + r(&mut rng, 100),
                                r(&mut rng, 120),
                                r(&mut rng, 120),
                                r(&mut rng, 120),
                            )
                        })
                        .collect();
                    kf(&format!("k{i}"), &pts)
                })
                .collect();
            // budgets that sometimes bind, sometimes don't
            let dev = tiny_device(
                40 + rng.next_u64() % 200,
                40 + rng.next_u64() % 200,
                40 + rng.next_u64() % 200,
            );
            let bb = allocate(&ks, &dev);
            let bf = allocate_brute(&ks, &dev);
            assert_eq!(
                bb.best.is_some(),
                bf.best.is_some(),
                "case {case}: feasibility disagreement"
            );
            if let (Some(x), Some(y)) = (&bb.best, &bf.best) {
                assert_eq!(x.choice, y.choice, "case {case}");
                assert_eq!(x.gflops.to_bits(), y.gflops.to_bits(), "case {case}");
                assert!(x.dsp <= dev.dsp_total as f64, "case {case}");
                assert!(x.onchip_bytes <= dev.onchip_bytes as f64, "case {case}");
                assert!(x.lut <= dev.lut_total as f64, "case {case}");
            }
            assert!(
                bb.nodes <= bf.nodes.max(1) * (nk as u64 + 1),
                "case {case}: b&b expanded implausibly many nodes \
                 ({} vs {} brute leaves)",
                bb.nodes,
                bf.nodes
            );
        }
    }
}
