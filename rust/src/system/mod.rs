//! System-level multi-kernel DSE (the `system` campaign mode).
//!
//! Real designs place several kernels on one device sharing
//! DSP/BRAM/LUT. This module composes two layers:
//!
//! 1. **Per-kernel fronts** — each kernel gets an epsilon-dominance
//!    Pareto front over `(latency, DSP, on-chip bytes, LUT)` from
//!    [`nlp::solve_front`](crate::nlp::solve_front): the solver's
//!    branch-and-bound run in exhaustive mode (incumbent guard
//!    disabled) with every incumbent reduced through the
//!    merge-order-invariant grid archive of [`crate::nlp::front`].
//! 2. **Budget allocation** — [`allocate`] picks exactly one front
//!    point per kernel maximizing total system throughput (GF/s, the
//!    sum of each kernel's [`Analysis::gflops`] at its chosen latency)
//!    subject to the summed DSP / on-chip-byte / LUT budget of the
//!    device, by depth-first branch-and-bound with admissible
//!    optimistic bounds. [`allocate_brute`] is the brute-force oracle
//!    the tests cross-check against on small instances.
//!
//! Determinism: per-kernel fronts are bit-identical across `jobs`
//! (solver reduction discipline), the archive is merge-order invariant,
//! and the allocator's DFS order plus strict-improvement rule makes the
//! chosen allocation a pure function of the fronts and the device.

pub mod allocate;

pub use allocate::{allocate, allocate_brute, AllocOutcome, Allocation};

use crate::hls::Device;
use crate::ir::Kernel;
use crate::nlp::{self, BatchEvaluator, FrontConfig, NlpProblem};
use crate::poly::Analysis;

/// Knobs of one system-mode run.
#[derive(Clone, Copy, Debug)]
pub struct SystemConfig {
    /// Per-kernel front extraction parameters.
    pub front: FrontConfig,
    /// Per-kernel partitioning cap handed to [`NlpProblem::new`].
    pub cap: u64,
    /// Per-kernel solver timeout, seconds.
    pub timeout_s: f64,
    /// Solver worker threads per kernel (kernels run sequentially; the
    /// solver parallelizes internally, keeping results `jobs`-invariant).
    pub jobs: usize,
}

impl Default for SystemConfig {
    fn default() -> SystemConfig {
        SystemConfig {
            front: FrontConfig::default(),
            cap: u64::MAX,
            timeout_s: 30.0,
            jobs: 1,
        }
    }
}

/// One kernel's extracted front plus the per-point throughput the
/// allocator maximizes over.
#[derive(Clone, Debug)]
pub struct KernelFront {
    /// Kernel name (reporting key).
    pub name: String,
    /// The epsilon-dominance front, canonical order.
    pub front: Vec<crate::nlp::FrontPoint>,
    /// GF/s of each front point (parallel to `front`): the kernel's
    /// exact flop count over the point's latency at device frequency.
    pub gflops: Vec<f64>,
    /// Proven latency lower bound from the solve.
    pub lower_bound: f64,
    /// Whether the per-kernel enumeration completed within budget.
    pub optimal: bool,
    /// Wall-clock of the per-kernel solve, seconds.
    pub solve_time_s: f64,
    /// Pipeline configurations processed (exactly-once accounting).
    pub configs: u64,
}

/// Everything one system-mode run produces.
#[derive(Clone, Debug)]
pub struct SystemOutcome {
    /// Per-kernel fronts, in input order.
    pub kernels: Vec<KernelFront>,
    /// The allocation search result (best choice + node count).
    pub alloc: AllocOutcome,
    /// Total wall-clock across the per-kernel solves, seconds.
    pub solve_time_s: f64,
}

/// Extract one kernel's front: exhaustive solve ([`nlp::solve_front`])
/// plus the per-point GF/s the allocator maximizes. Pure in its inputs
/// — the coordinator fans these out across its pool and reassembles by
/// index with no effect on the result.
pub fn kernel_front(
    name: &str,
    k: &Kernel,
    device: &Device,
    cfg: &SystemConfig,
    evaluator: &dyn BatchEvaluator,
) -> KernelFront {
    let a = Analysis::new(k);
    let p = NlpProblem::new(k, &a, device, cfg.cap, false);
    let fr = nlp::solve_front(&p, cfg.timeout_s, &cfg.front, evaluator, cfg.jobs);
    let gflops = fr
        .points
        .iter()
        .map(|pt| a.gflops(pt.latency, device.freq_hz))
        .collect();
    KernelFront {
        name: name.to_string(),
        front: fr.points,
        gflops,
        lower_bound: fr.lower_bound,
        optimal: fr.optimal,
        solve_time_s: fr.solve_time_s,
        configs: fr.stats.configs,
    }
}

/// Assemble per-kernel fronts (input order) into the final outcome by
/// running the budget allocation once.
pub fn assemble(fronts: Vec<KernelFront>, device: &Device) -> SystemOutcome {
    let alloc = allocate(&fronts, device);
    let solve_time_s = fronts.iter().map(|f| f.solve_time_s).sum();
    SystemOutcome {
        kernels: fronts,
        alloc,
        solve_time_s,
    }
}

/// Run the full system mode: extract one front per kernel, then
/// branch-and-bound the budget allocation. Kernels are solved in input
/// order; the returned outcome is deterministic for fixed inputs
/// (including across solver `jobs`).
pub fn solve_system(
    kernels: &[(String, Kernel)],
    device: &Device,
    cfg: &SystemConfig,
    evaluator: &dyn BatchEvaluator,
) -> SystemOutcome {
    let fronts = kernels
        .iter()
        .map(|(name, k)| kernel_front(name, k, device, cfg, evaluator))
        .collect();
    assemble(fronts, device)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks;
    use crate::ir::DType;
    use crate::nlp::SymbolicEvaluator;

    #[test]
    fn two_kernel_system_allocates_within_budget() {
        let dev = Device::u200();
        let kernels = vec![
            (
                "gemm".to_string(),
                benchmarks::kernel_gemm(16, 16, 16, DType::F32),
            ),
            (
                "bicg".to_string(),
                benchmarks::kernel_bicg(16, 16, DType::F32),
            ),
        ];
        let cfg = SystemConfig {
            cap: 64,
            front: FrontConfig {
                epsilon: 0.05,
                max_points: 8,
            },
            ..Default::default()
        };
        let out = solve_system(&kernels, &dev, &cfg, &SymbolicEvaluator);
        assert_eq!(out.kernels.len(), 2);
        for kf in &out.kernels {
            assert!(!kf.front.is_empty(), "{} produced an empty front", kf.name);
            assert!(kf.front.len() <= 8);
            assert_eq!(kf.front.len(), kf.gflops.len());
        }
        let best = out.alloc.best.as_ref().expect("u200 fits two small kernels");
        assert_eq!(best.choice.len(), 2);
        assert!(best.dsp <= dev.dsp_total as f64);
        assert!(best.onchip_bytes <= dev.onchip_bytes as f64);
        assert!(best.lut <= dev.lut_total as f64);
        assert!(best.gflops > 0.0);
    }

    #[test]
    fn system_outcome_is_jobs_invariant() {
        let dev = Device::u200();
        let kernels = vec![(
            "gemm".to_string(),
            benchmarks::kernel_gemm(12, 12, 12, DType::F32),
        )];
        let cfg1 = SystemConfig {
            cap: 32,
            ..Default::default()
        };
        let cfg4 = SystemConfig { jobs: 4, ..cfg1 };
        let o1 = solve_system(&kernels, &dev, &cfg1, &SymbolicEvaluator);
        let o4 = solve_system(&kernels, &dev, &cfg4, &SymbolicEvaluator);
        let (k1, k4) = (&o1.kernels[0], &o4.kernels[0]);
        assert_eq!(k1.front.len(), k4.front.len());
        for (p1, p4) in k1.front.iter().zip(&k4.front) {
            assert_eq!(p1.design, p4.design);
            assert_eq!(p1.latency.to_bits(), p4.latency.to_bits());
            assert_eq!(p1.lut.to_bits(), p4.lut.to_bits());
        }
        assert_eq!(
            o1.alloc.best.as_ref().map(|b| b.choice.clone()),
            o4.alloc.best.as_ref().map(|b| b.choice.clone())
        );
    }
}
