//! Data-dependence analysis.
//!
//! The paper's class restriction (pure polyhedral programs, Section 4.2)
//! makes dependence analysis *exact*. The IR's access functions are affine
//! and, across the evaluated suite, fall into forms this specialized tester
//! resolves exactly:
//!
//! * **identical** index functions (e.g. `tmp[i][j] += ...` over `k`) —
//!   the accumulation pattern: every nest loop *not* referenced by the index
//!   carries a distance-1 dependence (a *reduction* when no other self
//!   dependence serializes the statement — Theorem 4.7's tree-reduction
//!   precondition);
//! * **constant-shift** index functions, possibly on several dimensions
//!   (stencils: `A[i][j-1]`, `y[j-2]` — Listing 9, Eq 8's unroll cap): a
//!   carried dependence of constant distance on each shifted loop;
//! * **structurally different** index functions (`cov[j][i]` vs
//!   `cov[i][j]`, `path[i][k]` vs `path[i][j]`): carried by the outermost
//!   loop whose role differs between the two functions (exact for the
//!   transposition/propagation patterns in the suite, conservative
//!   otherwise);
//! * **cross-statement** dependences: shared loops absent from the index
//!   functions carry the dependence (the Jacobi/heat time loop).
//!
//! Outputs:
//! * [`LoopDepInfo`] per loop: carried?, min distance, reduction?, op;
//! * a statement dependence matrix (the `C` operator's sum-vs-max decision,
//!   Section 4.1);
//! * the flat dependence list (`ND` column of Table 5).

use crate::ir::{Access, ArrayId, Kernel, LoopId, OpKind, StmtId};
use std::collections::{BTreeMap, BTreeSet};

/// Dependence class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DepKind {
    /// Read-after-write (true dependence).
    Raw,
    /// Write-after-read (anti).
    War,
    /// Write-after-write (output).
    Waw,
}

/// One dependence edge.
#[derive(Clone, Debug)]
pub struct Dependence {
    /// Dependence class.
    pub kind: DepKind,
    /// Source statement.
    pub src: StmtId,
    /// Destination statement.
    pub dst: StmtId,
    /// Array carrying the dependence.
    pub array: ArrayId,
    /// Carrying loop and constant distance when known; `None` for
    /// loop-independent dependences.
    pub carried: Option<(LoopId, u64)>,
}

/// Per-loop summary consumed as NLP constants.
#[derive(Clone, Debug, Default)]
pub struct LoopDepInfo {
    /// Loop carries at least one dependence.
    pub carried: bool,
    /// Minimum constant carried distance (`d_l`; Eq 8 caps `UF <= d_l`).
    pub min_distance: Option<u64>,
    /// Loop is a reduction loop (associative accumulation; tree-reducible
    /// under unsafe-math, Theorem 4.7).
    pub reduction: bool,
    /// The reduction operation (drives `II >= IL_red` and tree latency).
    pub reduction_op: Option<OpKind>,
    /// Loop carries a non-reduction dependence: iterations must execute in
    /// order (no coarse-grained parallelization, no tree reduction).
    pub serializing: bool,
}

impl LoopDepInfo {
    /// A loop is *parallel* when it carries no dependence at all.
    pub fn parallel(&self) -> bool {
        !self.carried
    }
}

/// One component of a dependence direction/distance vector: the
/// constraint the dependence places on a single shared loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DirComp {
    /// Known constant signed distance on this loop (`Dist(0)` is the
    /// classical `=` direction: loop-independent at this level).
    Dist(i64),
    /// Carried with a strictly positive but non-constant distance (`<`).
    Pos,
    /// Unknown relation (`*`): the analysis cannot bound this loop's
    /// contribution, so any reordering against the other non-`=`
    /// components must be refused.
    Any,
}

impl DirComp {
    /// The `=` direction — distance zero at this level.
    pub fn is_eq(self) -> bool {
        self == DirComp::Dist(0)
    }
}

/// Full direction/distance vector of one dependence edge: the per-loop
/// constraints over the statement pair's shared nest, outermost first.
///
/// Vectors are normalized lexicographically non-negative: when the
/// leading constant component comes out negative the edge is flipped
/// (`src`/`dst` swapped, RAW ↔ WAR) and every constant component
/// negated, so `src` is always the side executing first. Transform
/// legality (loop interchange, distribution, fusion) is decided against
/// these vectors — see `transform::legality`.
#[derive(Clone, Debug, PartialEq)]
pub struct DirVector {
    /// Dependence class (after normalization).
    pub kind: DepKind,
    /// Source statement — executes first.
    pub src: StmtId,
    /// Destination statement.
    pub dst: StmtId,
    /// Array carrying the dependence.
    pub array: ArrayId,
    /// `(loop, component)` per shared-nest level, outermost first.
    pub entries: Vec<(LoopId, DirComp)>,
}

impl DirVector {
    /// Component for loop `l`, if `l` belongs to the shared nest.
    pub fn component(&self, l: LoopId) -> Option<DirComp> {
        self.entries.iter().find(|(x, _)| *x == l).map(|&(_, c)| c)
    }
    /// Every component is `=`: the dependence is loop-independent and
    /// only constrains textual statement order.
    pub fn loop_independent(&self) -> bool {
        self.entries.iter().all(|(_, c)| c.is_eq())
    }
    /// The outermost loop with a non-`=` component (the carrying
    /// level), if any.
    pub fn carrier(&self) -> Option<LoopId> {
        self.entries.iter().find(|(_, c)| !c.is_eq()).map(|&(l, _)| l)
    }
}

/// All dependence facts of one kernel.
pub struct DepAnalysis {
    /// Flat dependence list (`ND` column of Table 5).
    pub deps: Vec<Dependence>,
    /// Per-loop summary, by loop id.
    pub per_loop: Vec<LoopDepInfo>,
    /// Symmetric statement dependence relation (sum-vs-max composition).
    pub stmt_dep: Vec<Vec<bool>>,
    /// `(stmt, loop)` pairs where `loop` is a reduction loop *for that
    /// statement* (used by the per-statement II bound).
    pub stmt_reductions: Vec<(StmtId, LoopId, OpKind)>,
    /// Per-dependence direction/distance vectors (deduplicated), the
    /// legality substrate for pre-pragma loop transformations.
    pub dir_vectors: Vec<DirVector>,
}

impl DepAnalysis {
    /// Whether statements `a` and `b` depend on each other (symmetric).
    pub fn stmts_dependent(&self, a: StmtId, b: StmtId) -> bool {
        self.stmt_dep[a.0 as usize][b.0 as usize]
    }
    /// Paper's `ND` column: number of polyhedral dependences.
    pub fn nd(&self) -> usize {
        self.deps.len()
    }
    /// Per-loop summary of loop `l`.
    pub fn loop_info(&self, l: LoopId) -> &LoopDepInfo {
        &self.per_loop[l.0 as usize]
    }
    /// Reduction loops of one statement.
    pub fn reductions_of(&self, s: StmtId) -> impl Iterator<Item = (LoopId, OpKind)> + '_ {
        self.stmt_reductions
            .iter()
            .filter(move |(sid, ..)| *sid == s)
            .map(|&(_, l, op)| (l, op))
    }
    /// Direction/distance vectors whose edge touches both `a` and `b`
    /// (in either orientation; `a == b` selects self-dependences).
    pub fn vectors_between(
        &self,
        a: StmtId,
        b: StmtId,
    ) -> impl Iterator<Item = &DirVector> + '_ {
        self.dir_vectors
            .iter()
            .filter(move |v| (v.src == a && v.dst == b) || (v.src == b && v.dst == a))
    }
}

/// Relation between two affine access functions to the same array.
#[derive(Debug, PartialEq)]
enum IndexRel {
    /// Identical index functions.
    Identical,
    /// Every dimension identical or shifted by a constant on its (single)
    /// loop axis: a constant distance vector. Distances are signed with
    /// the convention `iter_b = iter_a + d` on the aliasing cell.
    ShiftVec(Vec<(LoopId, i64)>),
    /// Provably never equal (distinct constants on a loop-free dimension).
    Disjoint,
    /// Structurally different index functions; `involved` is the set of
    /// loops whose role differs between the two functions.
    Different { involved: BTreeSet<LoopId> },
}

fn index_relation(a: &Access, b: &Access) -> IndexRel {
    debug_assert_eq!(a.array, b.array);
    let mut shifts: Vec<(LoopId, i64)> = Vec::new();
    let mut involved: BTreeSet<LoopId> = BTreeSet::new();
    let mut different = false;
    for (ea, eb) in a.indices.iter().zip(&b.indices) {
        let diff = ea.sub(eb);
        if diff.is_constant() {
            if diff.constant == 0 {
                continue; // identical on this dim
            }
            match ea.terms.as_slice() {
                [(l, c)] if diff.constant % *c == 0 => {
                    shifts.push((*l, diff.constant / *c));
                }
                [] => return IndexRel::Disjoint, // a[0] vs a[1]
                _ => {
                    different = true;
                    involved.extend(ea.loops());
                }
            }
        } else {
            // different index functions on this dim (a[i][j] vs a[j][i],
            // path[i][j] vs path[i][k], ...)
            different = true;
            let la: BTreeSet<LoopId> = ea.loops().collect();
            let lb: BTreeSet<LoopId> = eb.loops().collect();
            involved.extend(la.symmetric_difference(&lb).copied());
            // transposed pattern: same loop set, different positions
            if la == lb {
                involved.extend(la);
            }
        }
    }
    if different {
        IndexRel::Different { involved }
    } else if shifts.is_empty() {
        IndexRel::Identical
    } else {
        IndexRel::ShiftVec(shifts)
    }
}

/// Per-loop direction components for the access pair `(a, b)` over the
/// `shared` nest (outermost first). A loop is pinned to an exact
/// constant distance only when some index dimension is a single-term
/// affine function of that loop on *both* sides with a divisible
/// constant difference (`c*x + k_a` vs `c*x + k_b`); any appearance in
/// a multi-term or structurally different dimension demotes the loop to
/// `Any`, as does a conflicting pin from a second dimension.
fn pair_components(a: &Access, b: &Access, shared: &[LoopId]) -> Vec<(LoopId, DirComp)> {
    // pinned: loop -> Some(distance) or None on conflicting pins
    let mut pinned: BTreeMap<LoopId, Option<i64>> = BTreeMap::new();
    let mut fuzzy: BTreeSet<LoopId> = BTreeSet::new();
    for (ea, eb) in a.indices.iter().zip(&b.indices) {
        match (ea.terms.as_slice(), eb.terms.as_slice()) {
            ([(la, ca)], [(lb, cb)])
                if la == lb && ca == cb && *ca != 0 && (ea.constant - eb.constant) % *ca == 0 =>
            {
                // cell equality forces iter_b = iter_a + d on this loop
                let d = (ea.constant - eb.constant) / *ca;
                pinned
                    .entry(*la)
                    .and_modify(|e| {
                        if *e != Some(d) {
                            *e = None;
                        }
                    })
                    .or_insert(Some(d));
            }
            _ => {
                fuzzy.extend(ea.loops());
                fuzzy.extend(eb.loops());
            }
        }
    }
    shared
        .iter()
        .map(|&l| {
            let comp = match pinned.get(&l) {
                Some(&Some(d)) if !fuzzy.contains(&l) => DirComp::Dist(d),
                _ => DirComp::Any,
            };
            (l, comp)
        })
        .collect()
}

/// Public wrapper over the pair classifier for transform legality:
/// per-loop components of the access pair `(a, b)` over `shared`
/// (outermost first), *un-normalized* — `Dist(d)` means the aliasing
/// cell satisfies `iter_b = iter_a + d` on that loop. Fusion legality
/// needs this raw orientation (which side was the first nest), which
/// the normalized [`DirVector`]s intentionally erase.
pub fn access_pair_components(
    a: &Access,
    b: &Access,
    shared: &[LoopId],
) -> Vec<(LoopId, DirComp)> {
    pair_components(a, b, shared)
}

/// Build the normalized direction/distance vector of one access pair.
fn build_vector(
    kind: DepKind,
    src: StmtId,
    dst: StmtId,
    array: ArrayId,
    shared: &[LoopId],
    a: &Access,
    b: &Access,
) -> DirVector {
    let mut entries = pair_components(a, b, shared);
    if src == dst {
        // Self-dependence refinement: iterations pinned equal on every
        // other level must differ — strictly forward in time — on a
        // sole unconstrained loop (the accumulation pattern: gemm's k).
        let idx_loops: BTreeSet<LoopId> = a
            .indices
            .iter()
            .chain(b.indices.iter())
            .flat_map(|e| e.loops().collect::<Vec<_>>())
            .collect();
        let absent_any: Vec<usize> = entries
            .iter()
            .enumerate()
            .filter(|(_, (l, c))| *c == DirComp::Any && !idx_loops.contains(l))
            .map(|(i, _)| i)
            .collect();
        let any_total = entries.iter().filter(|(_, c)| *c == DirComp::Any).count();
        if absent_any.len() == 1
            && any_total == 1
            && entries.iter().all(|(_, c)| c.is_eq() || *c == DirComp::Any)
        {
            entries[absent_any[0]].1 = DirComp::Pos;
        }
    }
    // Lexicographic normalization: a leading negative constant means the
    // dependence actually flows the other way.
    let (mut kind, mut src, mut dst) = (kind, src, dst);
    let lead = entries.iter().find_map(|&(_, c)| match c {
        DirComp::Dist(0) => None,
        c => Some(c),
    });
    if let Some(DirComp::Dist(d)) = lead {
        if d < 0 {
            for (_, c) in entries.iter_mut() {
                if let DirComp::Dist(x) = c {
                    *c = DirComp::Dist(-*x);
                }
            }
            std::mem::swap(&mut src, &mut dst);
            kind = match kind {
                DepKind::Raw => DepKind::War,
                DepKind::War => DepKind::Raw,
                DepKind::Waw => DepKind::Waw,
            };
        }
    }
    DirVector {
        kind,
        src,
        dst,
        array,
        entries,
    }
}

/// Run the analysis.
pub fn analyze(k: &Kernel) -> DepAnalysis {
    let n_stmts = k.n_stmts();
    let mut deps: Vec<Dependence> = Vec::new();
    let mut per_loop: Vec<LoopDepInfo> = vec![LoopDepInfo::default(); k.n_loops()];
    let mut stmt_dep = vec![vec![false; n_stmts]; n_stmts];
    // pending (stmt, loop, op) reduction candidates; demoted to serializing
    // if the statement turns out to have serializing self-dependences
    let mut pending_red: Vec<(StmtId, LoopId, OpKind)> = Vec::new();
    let mut stmt_serializing_self: Vec<bool> = vec![false; n_stmts];

    let stmt_ids: Vec<StmtId> = (0..n_stmts as u32).map(StmtId).collect();

    // -- self dependences ---------------------------------------------------
    for &s in &stmt_ids {
        let nest = k.stmt_meta(s).nest.clone();
        let st = k.stmt(s).clone();
        for w in &st.writes {
            for (r, kind) in st
                .reads
                .iter()
                .map(|r| (r, DepKind::Raw))
                .chain(st.writes.iter().map(|r| (r, DepKind::Waw)))
            {
                if w.array != r.array || std::ptr::eq(w, r) {
                    continue;
                }
                match index_relation(w, r) {
                    IndexRel::Identical => {
                        // accumulation: nest loops absent from the index
                        let idx_loops: BTreeSet<LoopId> = w
                            .indices
                            .iter()
                            .flat_map(|e| e.loops().collect::<Vec<_>>())
                            .collect();
                        if let Some(op) = reduction_op(&st) {
                            for &l in &nest {
                                if !idx_loops.contains(&l) {
                                    pending_red.push((s, l, op));
                                    deps.push(Dependence {
                                        kind,
                                        src: s,
                                        dst: s,
                                        array: w.array,
                                        carried: Some((l, 1)),
                                    });
                                }
                            }
                        }
                        if kind == DepKind::Raw {
                            stmt_dep[s.0 as usize][s.0 as usize] = true;
                        }
                    }
                    IndexRel::ShiftVec(shifts) => {
                        // constant distance vector: each shifted loop in the
                        // nest carries with its distance
                        for (l, d) in shifts {
                            let d = d.unsigned_abs();
                            if d == 0 || !nest.contains(&l) {
                                continue;
                            }
                            let info = &mut per_loop[l.0 as usize];
                            info.carried = true;
                            info.serializing = true;
                            info.min_distance =
                                Some(info.min_distance.map_or(d, |x| x.min(d)));
                            stmt_serializing_self[s.0 as usize] = true;
                            deps.push(Dependence {
                                kind,
                                src: s,
                                dst: s,
                                array: w.array,
                                carried: Some((l, d)),
                            });
                        }
                    }
                    IndexRel::Different { involved } => {
                        // carried by the outermost involved loop of the nest
                        if let Some(&l) = nest.iter().find(|l| involved.contains(l)) {
                            let info = &mut per_loop[l.0 as usize];
                            info.carried = true;
                            info.serializing = true;
                            stmt_serializing_self[s.0 as usize] = true;
                            deps.push(Dependence {
                                kind,
                                src: s,
                                dst: s,
                                array: w.array,
                                carried: Some((l, 1)),
                            });
                        }
                    }
                    IndexRel::Disjoint => {}
                }
            }
        }
    }

    // -- cross-statement dependences ----------------------------------------
    for (i, &s1) in stmt_ids.iter().enumerate() {
        for &s2 in stmt_ids.iter().skip(i + 1) {
            let nest1 = &k.stmt_meta(s1).nest;
            let nest2 = &k.stmt_meta(s2).nest;
            let shared: Vec<LoopId> = nest1
                .iter()
                .filter(|l| nest2.contains(l))
                .copied()
                .collect();
            for (a1, w1) in k.stmt_accesses(s1) {
                for (a2, w2) in k.stmt_accesses(s2) {
                    if a1.array != a2.array || (!w1 && !w2) {
                        continue;
                    }
                    let kind = match (w1, w2) {
                        (true, true) => DepKind::Waw,
                        (true, false) => DepKind::Raw,
                        (false, true) => DepKind::War,
                        _ => unreachable!(),
                    };
                    let rel = index_relation(a1, a2);
                    if rel == IndexRel::Disjoint {
                        continue;
                    }
                    stmt_dep[s1.0 as usize][s2.0 as usize] = true;
                    stmt_dep[s2.0 as usize][s1.0 as usize] = true;

                    // shared loops absent from both index functions carry
                    // the dependence across iterations (jacobi time loop)
                    let idx_loops: BTreeSet<LoopId> = a1
                        .indices
                        .iter()
                        .chain(a2.indices.iter())
                        .flat_map(|e| e.loops().collect::<Vec<_>>())
                        .collect();
                    let mut carried = None;
                    for &l in &shared {
                        if !idx_loops.contains(&l) {
                            let info = &mut per_loop[l.0 as usize];
                            info.carried = true;
                            info.serializing = true;
                            info.min_distance =
                                Some(info.min_distance.map_or(1, |x| x.min(1)));
                            carried = Some((l, 1u64));
                        }
                    }
                    // shifted shared loop (producer/consumer stencil pair)
                    if let IndexRel::ShiftVec(ref shifts) = rel {
                        for &(l, d) in shifts {
                            let d = d.unsigned_abs();
                            if d >= 1 && shared.contains(&l) {
                                let info = &mut per_loop[l.0 as usize];
                                info.carried = true;
                                info.serializing = true;
                                info.min_distance =
                                    Some(info.min_distance.map_or(d, |x| x.min(d)));
                                carried = carried.or(Some((l, d)));
                            }
                        }
                    }
                    deps.push(Dependence {
                        kind,
                        src: s1,
                        dst: s2,
                        array: a1.array,
                        carried,
                    });
                }
            }
        }
    }

    // -- resolve pending reductions -----------------------------------------
    let mut stmt_reductions: Vec<(StmtId, LoopId, OpKind)> = Vec::new();
    for (s, l, op) in pending_red {
        let info = &mut per_loop[l.0 as usize];
        info.carried = true;
        info.min_distance = Some(info.min_distance.map_or(1, |x| x.min(1)));
        if stmt_serializing_self[s.0 as usize] || info.serializing {
            // the statement also has order-enforcing self deps (stencil /
            // floyd-warshall): tree reduction is illegal, iterations are
            // sequential on this loop
            info.serializing = true;
        } else {
            info.reduction = true;
            info.reduction_op = Some(info.reduction_op.unwrap_or(op));
            stmt_reductions.push((s, l, op));
        }
    }

    // -- direction/distance vectors ------------------------------------------
    // A clean second pass over the same access pairs: one normalized
    // vector per (pair, kind), deduplicated. Self-vectors that are
    // loop-independent (all `=`) constrain nothing and are dropped.
    let mut dir_vectors: Vec<DirVector> = Vec::new();
    let mut push_vec = |v: DirVector| {
        if !dir_vectors.contains(&v) {
            dir_vectors.push(v);
        }
    };
    for &s in &stmt_ids {
        let nest = k.stmt_meta(s).nest.clone();
        let st = k.stmt(s).clone();
        for w in &st.writes {
            for (r, kind) in st
                .reads
                .iter()
                .map(|r| (r, DepKind::Raw))
                .chain(st.writes.iter().map(|r| (r, DepKind::Waw)))
            {
                if w.array != r.array || std::ptr::eq(w, r) {
                    continue;
                }
                if index_relation(w, r) == IndexRel::Disjoint {
                    continue;
                }
                let v = build_vector(kind, s, s, w.array, &nest, w, r);
                if !v.loop_independent() {
                    push_vec(v);
                }
            }
        }
    }
    for (i, &s1) in stmt_ids.iter().enumerate() {
        for &s2 in stmt_ids.iter().skip(i + 1) {
            let nest1 = &k.stmt_meta(s1).nest;
            let nest2 = &k.stmt_meta(s2).nest;
            let shared: Vec<LoopId> = nest1
                .iter()
                .filter(|l| nest2.contains(l))
                .copied()
                .collect();
            for (a1, w1) in k.stmt_accesses(s1) {
                for (a2, w2) in k.stmt_accesses(s2) {
                    if a1.array != a2.array || (!w1 && !w2) {
                        continue;
                    }
                    if index_relation(a1, a2) == IndexRel::Disjoint {
                        continue;
                    }
                    let kind = match (w1, w2) {
                        (true, true) => DepKind::Waw,
                        (true, false) => DepKind::Raw,
                        (false, true) => DepKind::War,
                        _ => unreachable!(),
                    };
                    push_vec(build_vector(kind, s1, s2, a1.array, &shared, a1, a2));
                }
            }
        }
    }

    DepAnalysis {
        deps,
        per_loop,
        stmt_dep,
        stmt_reductions,
        dir_vectors,
    }
}

/// The associative op of an accumulation statement (`+`/`-` preferred, then
/// `*`) — tree-reducible under Vitis unsafe-math (Section 4.2.2).
fn reduction_op(s: &crate::ir::Stmt) -> Option<OpKind> {
    if s.op_count(OpKind::Add) > 0 {
        Some(OpKind::Add)
    } else if s.op_count(OpKind::Sub) > 0 {
        Some(OpKind::Sub)
    } else if s.op_count(OpKind::Mul) > 0 {
        Some(OpKind::Mul)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArrayDir, DType, KernelBuilder};

    #[test]
    fn gemm_k_is_reduction() {
        let k = crate::benchmarks::kernel_gemm(16, 18, 20, DType::F32);
        let da = analyze(&k);
        assert!(!da.per_loop[0].carried, "i must be parallel");
        assert!(!da.per_loop[1].carried, "j must be parallel");
        assert!(da.per_loop[2].reduction, "k must be a reduction");
        assert_eq!(da.per_loop[2].reduction_op, Some(OpKind::Add));
        assert_eq!(da.per_loop[2].min_distance, Some(1));
        assert!(!da.per_loop[2].serializing);
        assert!(da.nd() > 0);
    }

    #[test]
    fn distance_two_recurrence() {
        // for j in [2,N): y[j] = y[j-2] + 3  (Listing 9)
        let mut kb = KernelBuilder::new("rec2", DType::F32);
        let y = kb.array("y", &[100], ArrayDir::InOut);
        kb.for_const("j", 2, 100, |kb, j| {
            kb.stmt(
                "S0",
                vec![kb.at(y, &[kb.v(j)])],
                vec![kb.at(y, &[kb.vp(j, -2)])],
                &[(OpKind::Add, 1)],
            );
        });
        let k = kb.finish();
        let da = analyze(&k);
        assert!(da.per_loop[0].carried);
        assert_eq!(da.per_loop[0].min_distance, Some(2));
        assert!(da.per_loop[0].serializing);
        assert!(!da.per_loop[0].reduction);
    }

    #[test]
    fn seidel_fully_serial_no_tree_reduction() {
        let k = crate::benchmarks::kernel_seidel_2d(10, 40, DType::F32);
        let da = analyze(&k);
        // all three loops (t, i, j) carry order-enforcing deps
        for l in 0..3 {
            assert!(da.per_loop[l].serializing, "seidel loop {l} must serialize");
            assert!(!da.per_loop[l].reduction, "seidel loop {l} is not tree-reducible");
        }
    }

    #[test]
    fn jacobi_time_loop_carries_inner_parallel() {
        let k = crate::benchmarks::kernel_jacobi_1d(10, 40, DType::F32);
        let da = analyze(&k);
        assert!(da.per_loop[0].serializing, "t carries");
        assert!(!da.per_loop[1].carried, "i of S0 is parallel");
        assert!(!da.per_loop[2].carried, "i of S1 is parallel");
    }

    #[test]
    fn floyd_warshall_k_serial_ij_parallel() {
        let k = crate::benchmarks::kernel_floyd_warshall(30, DType::F32);
        let da = analyze(&k);
        assert!(da.per_loop[0].serializing, "k loop must serialize");
        assert!(!da.per_loop[0].reduction);
        assert!(!da.per_loop[1].carried, "i parallel for fixed k");
        assert!(!da.per_loop[2].carried, "j parallel for fixed k");
    }

    #[test]
    fn independent_statements_max_compose() {
        let k = crate::benchmarks::kernel_bicg(30, 34, DType::F32);
        let da = analyze(&k);
        // S2 (s[j] +=) and S3 (q[i] +=) touch disjoint outputs but share
        // reads of A — reads alone do not create a dependence
        assert!(!da.stmts_dependent(StmtId(2), StmtId(3)));
    }

    #[test]
    fn raw_dependence_across_statements() {
        // 2mm: S1 writes tmp, S3 reads tmp → dependent
        let k = crate::benchmarks::kernel_2mm(18, 19, 21, 22, DType::F32);
        let da = analyze(&k);
        assert!(da.stmts_dependent(StmtId(1), StmtId(3)));
    }

    #[test]
    fn atax_outer_loop_is_reduction_for_y() {
        let k = crate::benchmarks::kernel_atax(19, 21, DType::F32);
        let da = analyze(&k);
        // y[j] += A[i][j]*tmp[i]: i carries an additive reduction
        let has_i_red = da
            .stmt_reductions
            .iter()
            .any(|&(_, l, op)| op == OpKind::Add && da.per_loop[l.0 as usize].reduction);
        assert!(has_i_red);
    }

    #[test]
    fn gemm_direction_vector_is_eq_eq_pos() {
        let k = crate::benchmarks::kernel_gemm(16, 18, 20, DType::F32);
        let da = analyze(&k);
        // the += statement's self-RAW: (=, =, <) over (i, j, k)
        let v = da
            .dir_vectors
            .iter()
            .find(|v| v.src == v.dst && v.kind == DepKind::Raw && v.entries.len() == 3)
            .expect("gemm self-RAW vector");
        assert_eq!(v.entries[0].1, DirComp::Dist(0), "i is =");
        assert_eq!(v.entries[1].1, DirComp::Dist(0), "j is =");
        assert_eq!(v.entries[2].1, DirComp::Pos, "k is <");
        assert_eq!(v.carrier(), Some(v.entries[2].0));
    }

    #[test]
    fn distance_two_recurrence_vector() {
        let mut kb = KernelBuilder::new("rec2", DType::F32);
        let y = kb.array("y", &[100], ArrayDir::InOut);
        kb.for_const("j", 2, 100, |kb, j| {
            kb.stmt(
                "S0",
                vec![kb.at(y, &[kb.v(j)])],
                vec![kb.at(y, &[kb.vp(j, -2)])],
                &[(OpKind::Add, 1)],
            );
        });
        let da = analyze(&kb.finish());
        let v = da.vectors_between(StmtId(0), StmtId(0)).next().expect("vector");
        assert_eq!(v.kind, DepKind::Raw);
        assert_eq!(v.entries, vec![(LoopId(0), DirComp::Dist(2))]);
    }

    #[test]
    fn read_ahead_normalizes_to_forward_anti_dep() {
        // a[i] = a[i+1] * 2: the RAW pair points backwards; normalization
        // must flip it into a forward WAR of distance 1
        let mut kb = KernelBuilder::new("anti", DType::F32);
        let a = kb.array("a", &[64], ArrayDir::InOut);
        kb.for_const("i", 0, 63, |kb, i| {
            kb.stmt(
                "S0",
                vec![kb.at(a, &[kb.v(i)])],
                vec![kb.at(a, &[kb.vp(i, 1)])],
                &[(OpKind::Mul, 1)],
            );
        });
        let da = analyze(&kb.finish());
        let v = da.vectors_between(StmtId(0), StmtId(0)).next().expect("vector");
        assert_eq!(v.kind, DepKind::War, "read-ahead is an anti dependence");
        assert_eq!(v.entries, vec![(LoopId(0), DirComp::Dist(1))]);
    }

    #[test]
    fn output_dep_across_statements_is_loop_independent() {
        // S0 and S1 both write b[i] each iteration: WAW with vector (=)
        let mut kb = KernelBuilder::new("waw", DType::F32);
        let b = kb.array("b", &[64], ArrayDir::Out);
        let c = kb.array("c", &[64], ArrayDir::In);
        kb.for_const("i", 0, 64, |kb, i| {
            kb.stmt("S0", vec![kb.at(b, &[kb.v(i)])], vec![kb.at(c, &[kb.v(i)])], &[(OpKind::Add, 1)]);
            kb.stmt("S1", vec![kb.at(b, &[kb.v(i)])], vec![kb.at(c, &[kb.v(i)])], &[(OpKind::Mul, 1)]);
        });
        let da = analyze(&kb.finish());
        let v = da
            .vectors_between(StmtId(0), StmtId(1))
            .find(|v| v.kind == DepKind::Waw)
            .expect("WAW vector");
        assert!(v.loop_independent());
        assert_eq!(v.src, StmtId(0), "textual order orients the edge");
    }

    #[test]
    fn transposed_access_is_any_any() {
        // a[i][j] = a[j][i]: neither loop's distance is representable
        let mut kb = KernelBuilder::new("tr", DType::F32);
        let a = kb.array("a", &[32, 32], ArrayDir::InOut);
        kb.for_const("i", 0, 32, |kb, i| {
            kb.for_const("j", 0, 32, |kb, j| {
                kb.stmt(
                    "S0",
                    vec![kb.at(a, &[kb.v(i), kb.v(j)])],
                    vec![kb.at(a, &[kb.v(j), kb.v(i)])],
                    &[(OpKind::Add, 1)],
                );
            });
        });
        let da = analyze(&kb.finish());
        let v = da.vectors_between(StmtId(0), StmtId(0)).next().expect("vector");
        assert_eq!(v.component(LoopId(0)), Some(DirComp::Any));
        assert_eq!(v.component(LoopId(1)), Some(DirComp::Any));
    }

    #[test]
    fn triangular_bounds_keep_exact_distances() {
        // for i, for j in [0, i): a[i][j] = a[i-1][j] — triangular inner
        // bound, still an exact distance-1 vector on i
        let mut kb = KernelBuilder::new("tri", DType::F32);
        let a = kb.array("a", &[32, 32], ArrayDir::InOut);
        kb.for_const("i", 1, 32, |kb, i| {
            kb.for_expr("j", kb.c(0), kb.v(i), |kb, j| {
                kb.stmt(
                    "S0",
                    vec![kb.at(a, &[kb.v(i), kb.v(j)])],
                    vec![kb.at(a, &[kb.vp(i, -1), kb.v(j)])],
                    &[(OpKind::Add, 1)],
                );
            });
        });
        let da = analyze(&kb.finish());
        let v = da.vectors_between(StmtId(0), StmtId(0)).next().expect("vector");
        assert_eq!(v.entries[0].1, DirComp::Dist(1), "i carries distance 1");
        assert_eq!(v.entries[1].1, DirComp::Dist(0), "j is =");
    }

    #[test]
    fn jacobi_shared_time_loop_is_any() {
        let k = crate::benchmarks::kernel_jacobi_1d(10, 40, DType::F32);
        let da = analyze(&k);
        let t = LoopId(0);
        let cross: Vec<&DirVector> = da
            .dir_vectors
            .iter()
            .filter(|v| v.src != v.dst && v.component(t).is_some())
            .collect();
        assert!(!cross.is_empty(), "jacobi has cross-statement deps over t");
        for v in cross {
            assert_eq!(v.component(t), Some(DirComp::Any), "t is unbounded: {v:?}");
        }
    }

    #[test]
    fn vectors_are_deduplicated_and_normalized(){
        for (name, k) in [
            ("gemm", crate::benchmarks::kernel_gemm(8, 8, 8, DType::F32)),
            ("jacobi", crate::benchmarks::kernel_jacobi_1d(6, 16, DType::F32)),
            ("fw", crate::benchmarks::kernel_floyd_warshall(10, DType::F32)),
        ] {
            let da = analyze(&k);
            for (i, v) in da.dir_vectors.iter().enumerate() {
                assert!(
                    !da.dir_vectors[i + 1..].contains(v),
                    "{name}: duplicate vector {v:?}"
                );
                // normalization: the leading constant is never negative
                let lead = v.entries.iter().find(|(_, c)| !c.is_eq());
                if let Some(&(_, DirComp::Dist(d))) = lead {
                    assert!(d > 0, "{name}: lex-negative vector {v:?}");
                }
            }
        }
    }

    #[test]
    fn disjoint_constant_indices() {
        let mut kb = KernelBuilder::new("dis", DType::F32);
        let a = kb.array("a", &[4, 100], ArrayDir::InOut);
        kb.for_const("i", 0, 100, |kb, i| {
            kb.stmt(
                "S0",
                vec![kb.at(a, &[kb.c(0), kb.v(i)])],
                vec![kb.at(a, &[kb.c(1), kb.v(i)])],
                &[(OpKind::Add, 1)],
            );
        });
        let k = kb.finish();
        let da = analyze(&k);
        assert!(!da.per_loop[0].carried, "rows 0 and 1 are disjoint");
    }
}
