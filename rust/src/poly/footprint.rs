//! Array footprint analysis for memory-transfer bounds (Theorems 4.13/4.14)
//! and on-chip capacity constraints (Eq 12).
//!
//! For a cache pragma inserted above loop `l` (or at kernel top when `l` is
//! `None`), the footprint of array `a` is the number of distinct elements
//! touched by the sub-computation underneath, for one iteration of the
//! enclosing loops. For affine accesses over box-like (or triangular)
//! domains the element set per dimension is an interval; the product of
//! interval widths is exact for the PolyBench access patterns (single
//! iterator ± constant per dimension) and a safe over-approximation
//! otherwise — over-approximating footprints keeps Eq 12 conservative while
//! the *transfer* lower bound uses the full-array footprint, which is exact.

use crate::ir::{Kernel, LoopId};
use std::collections::BTreeMap;

/// Per-array footprint (in elements) of the sub-computation under `level`.
pub fn footprint_elements(k: &Kernel, level: Option<LoopId>) -> BTreeMap<crate::ir::ArrayId, u64> {
    // iterator ranges: loops at-or-under `level` vary over their full
    // range; loops outside are "fixed" → contribute a single point (width 0)
    let varying: Vec<bool> = match level {
        None => vec![true; k.n_loops()],
        Some(root) => {
            let mut v = vec![false; k.n_loops()];
            for l in k.nest_loops(root) {
                v[l.0 as usize] = true;
            }
            v
        }
    };

    // Absolute iterator value ranges for every loop (outer loops fixed at
    // their midpoint would under-count; for footprint widths only varying
    // loops contribute spread, fixed loops contribute 0 spread).
    let tcs = super::tripcount::trip_counts(k);
    let ranges = |l: LoopId| -> (i64, i64) {
        if varying[l.0 as usize] {
            // conservative absolute range [0, TC_max - 1] shifted by the
            // loop's absolute lower bound — computing the absolute min is
            // enough for widths since widths are translation-invariant
            (0, tcs[l.0 as usize].max.max(1) as i64 - 1)
        } else {
            (0, 0)
        }
    };

    let mut out: BTreeMap<crate::ir::ArrayId, u64> = BTreeMap::new();
    let stmt_in_scope = |sid: crate::ir::StmtId| -> bool {
        match level {
            None => true,
            Some(root) => k.stmt_meta(sid).nest.contains(&root),
        }
    };
    for s in k.stmts() {
        if !stmt_in_scope(s.id) {
            continue;
        }
        for (acc, _w) in k.stmt_accesses(s.id) {
            let arr = k.array(acc.array);
            let mut elems: u64 = 1;
            for (d, idx) in acc.indices.iter().enumerate() {
                let (lo, hi) = idx.bounds(&ranges);
                let width = ((hi - lo + 1).max(1) as u64).min(arr.dims[d]);
                elems = elems.saturating_mul(width);
            }
            let e = out.entry(acc.array).or_insert(0);
            *e = (*e).max(elems);
        }
    }
    out
}

/// Footprint of array `a` in **bytes** under cache level `level`.
pub fn footprint_bytes(k: &Kernel, a: crate::ir::ArrayId, level: Option<LoopId>) -> u64 {
    footprint_elements(k, level)
        .get(&a)
        .copied()
        .unwrap_or(0)
        * k.dtype.bits() as u64
        / 8
}

/// Total kernel footprint in bytes (all arrays, full extent) — the paper's
/// per-kernel "footprint" figures (e.g. 2mm M ≈ 773 kB).
pub fn total_footprint_bytes(k: &Kernel) -> u64 {
    k.arrays
        .iter()
        .map(|a| a.footprint_bytes(k.dtype))
        .sum()
}

#[cfg(test)]
mod tests {
    use crate::ir::DType;

    #[test]
    fn full_kernel_footprints_match_paper() {
        // Paper §2.2: 2mm medium footprint ≈ 773 kB, gemm medium ≈ 579 kB
        let k2mm = crate::benchmarks::kernel_2mm(180, 190, 210, 220, DType::F32);
        let fp = super::total_footprint_bytes(&k2mm) as f64 / 1024.0;
        assert!(
            (700.0..850.0).contains(&fp),
            "2mm medium footprint {fp} kB, paper says ~773 kB"
        );

        let kgemm = crate::benchmarks::kernel_gemm(200, 220, 240, DType::F32);
        let fp = super::total_footprint_bytes(&kgemm) as f64 / 1024.0;
        assert!(
            (520.0..640.0).contains(&fp),
            "gemm medium footprint {fp} kB, paper says ~579 kB"
        );
    }

    #[test]
    fn sub_nest_footprint_smaller() {
        let k = crate::benchmarks::kernel_2mm(180, 190, 210, 220, DType::F32);
        let roots = k.nest_roots();
        let full = super::footprint_elements(&k, None);
        let nest0 = super::footprint_elements(&k, Some(roots[0]));
        // nest 0 touches tmp, A, B (not C, D)
        assert!(nest0.len() < full.len());
        for (a, e) in &nest0 {
            assert!(e <= &full[a]);
        }
    }

    #[test]
    fn footprint_clamped_to_array_dims() {
        use crate::ir::{ArrayDir, KernelBuilder, OpKind};
        // access a[i+1] over i in [0, 10) with dim 10 → width clamped to 10
        let mut kb = KernelBuilder::new("clamp", DType::F32);
        let a = kb.array("a", &[10], ArrayDir::InOut);
        kb.for_const("i", 0, 10, |kb, i| {
            kb.stmt(
                "S0",
                vec![kb.at(a, &[kb.v(i)])],
                vec![kb.at(a, &[kb.vp(i, 1)])],
                &[(OpKind::Add, 1)],
            );
        });
        let k = kb.finish();
        let fp = super::footprint_elements(&k, None);
        assert_eq!(fp[&crate::ir::ArrayId(0)], 10);
    }
}
