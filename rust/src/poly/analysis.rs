//! One-stop static analysis bundle: everything the model / NLP / simulators
//! need about a kernel, computed once.

use super::deps::{self, DepAnalysis};
use super::footprint;
use super::tripcount::{self, TripCount};
use crate::ir::{Kernel, LoopId, OpKind, StmtId};
use std::collections::BTreeMap;

/// The complete static-analysis bundle of one kernel.
pub struct Analysis {
    /// Per-loop trip counts, by loop id.
    pub tcs: Vec<TripCount>,
    /// Dependence analysis (distances, reductions, serialization).
    pub deps: DepAnalysis,
    /// Exact iteration count of each statement (product of enclosing
    /// `TC_avg`, exact for one level of affine-triangular nesting).
    pub stmt_iters: Vec<f64>,
    /// Total floating-point operations executed by the kernel.
    pub total_flops: f64,
    /// Full-extent footprint per array, bytes.
    pub array_footprints: BTreeMap<crate::ir::ArrayId, u64>,
    /// Total kernel footprint, bytes.
    pub total_footprint: u64,
}

impl Analysis {
    /// Run every analysis on `k`.
    pub fn new(k: &Kernel) -> Analysis {
        let tcs = tripcount::trip_counts(k);
        let deps = deps::analyze(k);
        let mut stmt_iters = vec![0f64; k.n_stmts()];
        let mut total_flops = 0f64;
        for s in k.stmts() {
            let iters: f64 = k
                .stmt_meta(s.id)
                .nest
                .iter()
                .map(|l| tcs[l.0 as usize].avg)
                .product();
            stmt_iters[s.id.0 as usize] = iters;
            total_flops += iters * s.flops() as f64;
        }
        let array_footprints = k
            .arrays
            .iter()
            .map(|a| (a.id, a.footprint_bytes(k.dtype)))
            .collect();
        let total_footprint = footprint::total_footprint_bytes(k);
        Analysis {
            tcs,
            deps,
            stmt_iters,
            total_flops,
            array_footprints,
            total_footprint,
        }
    }

    /// Trip count of loop `l`.
    pub fn tc(&self, l: LoopId) -> &TripCount {
        &self.tcs[l.0 as usize]
    }

    /// Number of `op` operations executed per iteration of statement `s`.
    pub fn stmt_op_count(&self, k: &Kernel, s: StmtId, op: OpKind) -> u32 {
        k.stmt(s).op_count(op)
    }

    /// GF/s for a given total latency in cycles at `freq_hz`.
    pub fn gflops(&self, cycles: f64, freq_hz: f64) -> f64 {
        if cycles <= 0.0 {
            return 0.0;
        }
        self.total_flops / (cycles / freq_hz) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use crate::ir::DType;

    #[test]
    fn gemm_flop_count_exact() {
        // gemm: S0 C*=beta (1 mul) NI*NJ times; S1 (2 mul + 1 add... our
        // def: C += alpha*A*B → 2 mul 1 add) NI*NJ*NK times
        let k = crate::benchmarks::kernel_gemm(200, 220, 240, DType::F32);
        let a = super::Analysis::new(&k);
        let expected = 200.0 * 220.0 * (1.0 + 240.0 * 3.0);
        assert!(
            (a.total_flops - expected).abs() / expected < 1e-12,
            "flops {} vs {expected}",
            a.total_flops
        );
    }

    #[test]
    fn gflops_arithmetic() {
        let k = crate::benchmarks::kernel_gemm(200, 220, 240, DType::F32);
        let a = super::Analysis::new(&k);
        // at 250 MHz, latency == flops cycles → 0.25 GF/s
        let g = a.gflops(a.total_flops, 250e6);
        assert!((g - 0.25).abs() < 1e-9);
    }

    #[test]
    fn triangular_iters_counted() {
        let k = crate::benchmarks::kernel_lu(40, DType::F32);
        let a = super::Analysis::new(&k);
        assert!(a.total_flops > 0.0);
        // lu has ~2/3 N^3 flops; sanity: between N^3/3 and N^3*1.5
        let n = 40f64;
        assert!(a.total_flops > n * n * n / 3.0 * 0.5);
        assert!(a.total_flops < n * n * n * 3.0);
    }
}
