//! Exact trip-count analysis.
//!
//! Every loop has half-open affine bounds `[lb, ub)` over enclosing
//! iterators. Trip counts are `TC = ub - lb` (clamped at 0), with:
//!
//! * `TC_min` / `TC_max`: exact extremes of `ub - lb` over the enclosing
//!   iteration box (affine ⇒ extremes at corners — `AffineExpr::bounds`);
//! * `TC_avg`: exact expectation of `ub - lb` when enclosing iterators are
//!   uniform over their ranges (affine ⇒ expectation at midpoints). This is
//!   the `TC^avg` the paper's latency template uses for triangular loops.
//!
//! These are the `TC_i^{min}`, `TC_i^{max}` entries of the per-loop property
//! vector PV (Section 3.1).

use crate::ir::{Kernel, LoopId};
use std::collections::BTreeMap;

/// Iteration-count summary of one loop (PV entries, Section 3.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TripCount {
    /// Minimum trip count over the iteration domain.
    pub min: u64,
    /// Maximum trip count (`TC^max`; the divisor menu base).
    pub max: u64,
    /// Average trip count (exact for affine-triangular nests).
    pub avg: f64,
}

impl TripCount {
    /// A loop is unrollable by Vitis only when its trip count is constant
    /// (Section 3.1: "Only a loop with a constant TC can be unrolled").
    pub fn is_constant(&self) -> bool {
        self.min == self.max
    }
}

/// Compute trip counts for every loop of `k`, in `LoopId` order.
pub fn trip_counts(k: &Kernel) -> Vec<TripCount> {
    // Iterator value ranges [lo, hi] (inclusive) and midpoints, computed
    // outside-in (loop ids are assigned pre-order, so parents precede
    // children — but don't rely on it; recurse through loop_path instead).
    let mut ranges: BTreeMap<LoopId, (i64, i64)> = BTreeMap::new();
    let mut mids: BTreeMap<LoopId, f64> = BTreeMap::new();
    let mut out: Vec<Option<TripCount>> = vec![None; k.n_loops()];

    // Process in pre-order via nest traversal to guarantee parents first.
    let mut order: Vec<LoopId> = Vec::new();
    for root in k.nest_roots() {
        collect_preorder(k, root, &mut order);
    }

    for l in order {
        let (lb, ub) = k.loop_bounds(l);
        let rng = |x: LoopId| *ranges.get(&x).expect("outer loop range missing");
        let (lb_lo, lb_hi) = lb.bounds(&rng);
        let (ub_lo, ub_hi) = ub.bounds(&rng);
        // tc extremes: (ub - lb) over the box
        let tc_expr = ub.sub(lb);
        let (tc_lo, tc_hi) = tc_expr.bounds(&rng);
        let min = tc_lo.max(0) as u64;
        let max = tc_hi.max(0) as u64;
        // average at midpoints of enclosing iterators
        let avg_env: f64 = {
            let mut acc = tc_expr.constant as f64;
            for &(dep, c) in &tc_expr.terms {
                acc += c as f64 * mids[&dep];
            }
            acc.max(0.0)
        };
        out[l.0 as usize] = Some(TripCount {
            min,
            max,
            avg: avg_env,
        });
        // iterator value range for children: [lb_lo, ub_hi - 1]
        ranges.insert(l, (lb_lo, (ub_hi - 1).max(lb_lo)));
        mids.insert(l, (lb_lo as f64 + lb_hi as f64) / 2.0 / 2.0 + (ub_lo as f64 + ub_hi as f64 - 2.0) / 4.0);
        // midpoint of iterator values: average of (avg lb) and (avg ub - 1)
    }

    out.into_iter().map(|t| t.unwrap()).collect()
}

fn collect_preorder(k: &Kernel, l: LoopId, out: &mut Vec<LoopId>) {
    out.push(l);
    for &c in &k.loop_meta(l).children {
        collect_preorder(k, c, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArrayDir, DType, KernelBuilder, OpKind};

    #[test]
    fn constant_bounds() {
        let k = crate::benchmarks::kernel_2mm(180, 190, 210, 220, DType::F32);
        let tcs = trip_counts(&k);
        assert_eq!(tcs.len(), 6);
        assert_eq!(tcs[0], TripCount { min: 180, max: 180, avg: 180.0 });
        assert_eq!(tcs[2].max, 210);
        assert!(tcs.iter().all(|t| t.is_constant()));
    }

    #[test]
    fn triangular_loop_tc() {
        // for i in [0,10): for j in [0,i): TC_j in {0..9}, avg 4.5
        let mut kb = KernelBuilder::new("tri", DType::F32);
        let a = kb.array("a", &[10, 10], ArrayDir::InOut);
        kb.for_const("i", 0, 10, |kb, i| {
            kb.for_expr("j", kb.c(0), kb.v(i), |kb, j| {
                kb.stmt(
                    "S0",
                    vec![kb.at(a, &[kb.v(i), kb.v(j)])],
                    vec![kb.at(a, &[kb.v(i), kb.v(j)])],
                    &[(OpKind::Add, 1)],
                );
            });
        });
        let k = kb.finish();
        let tcs = trip_counts(&k);
        assert_eq!(tcs[0], TripCount { min: 10, max: 10, avg: 10.0 });
        assert_eq!(tcs[1].min, 0);
        assert_eq!(tcs[1].max, 9);
        assert!((tcs[1].avg - 4.5).abs() < 1e-9, "avg={}", tcs[1].avg);
        assert!(!tcs[1].is_constant());
    }

    #[test]
    fn shifted_triangular_tc() {
        // for i in [0,8): for j in [i+1, 8): TC_j = 7-i in {0..7}, avg 3.5
        let mut kb = KernelBuilder::new("tri2", DType::F32);
        let a = kb.array("a", &[8, 8], ArrayDir::InOut);
        kb.for_const("i", 0, 8, |kb, i| {
            kb.for_expr("j", kb.vp(i, 1), kb.c(8), |kb, j| {
                kb.stmt(
                    "S0",
                    vec![kb.at(a, &[kb.v(i), kb.v(j)])],
                    vec![kb.at(a, &[kb.v(j), kb.v(i)])],
                    &[(OpKind::Mul, 1)],
                );
            });
        });
        let k = kb.finish();
        let tcs = trip_counts(&k);
        assert_eq!(tcs[1].max, 7);
        assert_eq!(tcs[1].min, 0);
        assert!((tcs[1].avg - 3.5).abs() < 1e-9);
    }
}
