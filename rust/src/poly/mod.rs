//! Exact static analysis over the affine IR — the stand-in for the paper's
//! PolyOpt-HLS front-end (Section 7.1). Everything the NLP formulation
//! consumes as *constants* is produced here:
//!
//! * [`tripcount`] — per-loop `TC_min` / `TC_max` / `TC_avg`, exact for
//!   affine (incl. triangular) bounds.
//! * [`deps`] — data-dependence analysis: loop-carried distances (Eq 8
//!   caps), reduction-loop detection (Theorem 4.7 tree reductions, II
//!   recurrence bounds), statement dependence matrix (the `C` operator's
//!   sum-vs-max decision), and the paper's `ND` dependence count.
//! * [`footprint`] — per-array footprints at any cache insertion level
//!   (Theorem 4.13 memory-transfer bounds, Eq 12 on-chip capacity).
//! * [`analysis`] — one-stop [`analysis::Analysis`] aggregating all of the
//!   above plus total flop counts for GF/s accounting.

pub mod analysis;
pub mod deps;
pub mod footprint;
pub mod tripcount;

pub use analysis::Analysis;
pub use deps::{DepKind, Dependence, DirComp, DirVector, LoopDepInfo};
pub use tripcount::TripCount;
