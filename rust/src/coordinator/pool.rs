//! Minimal fixed-size thread pool (tokio is unavailable offline; the
//! coordinator's workload is embarrassingly parallel batch jobs, for which
//! a plain worker pool is the right tool anyway).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool draining a FIFO job queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("nlpdse-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            workers,
            tx: Some(tx),
        }
    }

    /// Enqueue a job; runs as soon as a worker frees up.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool joined")
            .send(Box::new(f))
            .expect("worker pool alive");
    }

    /// Close the queue and wait for all workers to drain.
    pub fn join(mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_gracefully() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
