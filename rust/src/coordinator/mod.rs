//! Campaign coordinator: runs the evaluation matrix (kernels × sizes ×
//! engines) across a thread pool and aggregates per-kernel rows for the
//! report generators.
//!
//! Threading model: PJRT handles are thread-affine, so when the XLA path
//! is enabled each worker thread loads its *own* copy of the artifact
//! (compile-once-per-worker, ~100 ms) and keeps it for all its jobs —
//! python never runs, and the artifact never crosses threads.

pub mod pool;

use crate::baselines::{self, AutoDseConfig, AutoDseOutcome, HarpConfig, HarpOutcome};
use crate::benchmarks::{self, Size};
use crate::dse::{self, DseConfig, DseOutcome};
use crate::hls::{Device, HlsOracle};
use crate::ir::DType;
use crate::nlp::{BatchEvaluator, RustFeatureEvaluator};
use crate::poly::Analysis;
use crate::pragma::{Design, Space};
use crate::runtime::{default_artifact_dir, XlaEvaluator};
use pool::ThreadPool;

/// Which engines to run per kernel instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Engines {
    pub nlpdse: bool,
    pub autodse: bool,
    pub harp: bool,
}

impl Engines {
    pub fn all() -> Engines {
        Engines {
            nlpdse: true,
            autodse: true,
            harp: true,
        }
    }
    pub fn nlp_only() -> Engines {
        Engines {
            nlpdse: true,
            autodse: false,
            harp: false,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CampaignConfig {
    pub kernels: Vec<(String, Size)>,
    pub dtype: DType,
    pub engines: Engines,
    pub threads: usize,
    /// Evaluate NLP candidates through the AOT XLA artifact.
    pub use_xla: bool,
    pub dse: DseConfig,
    pub autodse: AutoDseConfig,
    pub harp: HarpConfig,
}

impl CampaignConfig {
    /// The paper's main comparison matrix: all kernels × {M, L}, f32.
    pub fn paper_autodse() -> CampaignConfig {
        let mut kernels = Vec::new();
        for name in benchmarks::ALL {
            if name == "cnn" {
                kernels.push((name.to_string(), Size::Medium));
                continue;
            }
            kernels.push((name.to_string(), Size::Medium));
            kernels.push((name.to_string(), Size::Large));
        }
        CampaignConfig {
            kernels,
            dtype: DType::F32,
            engines: Engines {
                nlpdse: true,
                autodse: true,
                harp: false,
            },
            threads: num_threads(),
            use_xla: false,
            dse: DseConfig::default(),
            autodse: AutoDseConfig::default(),
            harp: HarpConfig::default(),
        }
    }

    /// The HARP comparison: S+M, f64, HARP ladder (Section 7.4).
    pub fn paper_harp() -> CampaignConfig {
        let mut kernels = Vec::new();
        for name in benchmarks::ALL {
            if name == "cnn" {
                continue;
            }
            kernels.push((name.to_string(), Size::Small));
            kernels.push((name.to_string(), Size::Medium));
        }
        CampaignConfig {
            kernels,
            dtype: DType::F64,
            engines: Engines {
                nlpdse: true,
                autodse: false,
                harp: true,
            },
            threads: num_threads(),
            use_xla: false,
            dse: DseConfig {
                ladder: DseConfig::harp_ladder(),
                ..DseConfig::default()
            },
            autodse: AutoDseConfig::default(),
            harp: HarpConfig::default(),
        }
    }

    /// A fast sanity scope (small sizes, a handful of kernels).
    pub fn quick() -> CampaignConfig {
        let kernels = ["gemm", "2mm", "bicg", "atax", "mvt"]
            .iter()
            .map(|n| (n.to_string(), Size::Small))
            .collect();
        CampaignConfig {
            kernels,
            dtype: DType::F32,
            engines: Engines::all(),
            threads: num_threads(),
            use_xla: false,
            dse: DseConfig::default(),
            autodse: AutoDseConfig::default(),
            harp: HarpConfig {
                sweep_configs: 5_000,
                ..HarpConfig::default()
            },
        }
    }
}

pub fn num_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// One kernel-instance row: everything the tables need.
#[derive(Clone, Debug)]
pub struct KernelRow {
    pub name: String,
    pub size: Size,
    pub nl: usize,
    pub nd: usize,
    pub space_size: f64,
    pub footprint_bytes: u64,
    pub original_gflops: f64,
    pub nlpdse: Option<DseOutcome>,
    pub autodse: Option<AutoDseOutcome>,
    pub harp: Option<HarpOutcome>,
}

#[derive(Clone, Debug, Default)]
pub struct CampaignResult {
    pub rows: Vec<KernelRow>,
}

/// Run the campaign across the thread pool.
pub fn run_campaign(cfg: &CampaignConfig) -> CampaignResult {
    let pool = ThreadPool::new(cfg.threads);
    let (tx, rx) = std::sync::mpsc::channel::<(usize, KernelRow)>();
    let n_jobs = cfg.kernels.len();

    for (idx, (name, size)) in cfg.kernels.iter().cloned().enumerate() {
        let tx = tx.clone();
        let cfg = cfg.clone();
        pool.execute(move || {
            let row = run_one(&cfg, &name, size);
            let _ = tx.send((idx, row));
        });
    }
    drop(tx);

    let mut rows: Vec<Option<KernelRow>> = vec![None; n_jobs];
    for (idx, row) in rx {
        rows[idx] = Some(row);
    }
    pool.join();
    CampaignResult {
        rows: rows.into_iter().flatten().collect(),
    }
}

/// Process one kernel instance (runs inside a worker thread).
pub fn run_one(cfg: &CampaignConfig, name: &str, size: Size) -> KernelRow {
    let k = benchmarks::build(name, size, cfg.dtype)
        .unwrap_or_else(|| panic!("unknown kernel {name}"));
    let a = Analysis::new(&k);
    let dev = Device::u200();

    // each worker gets its own evaluator (PJRT is thread-affine)
    let xla_eval = if cfg.use_xla {
        XlaEvaluator::load(&default_artifact_dir()).ok()
    } else {
        None
    };
    let evaluator: &dyn BatchEvaluator = match &xla_eval {
        Some(e) => e,
        None => &RustFeatureEvaluator,
    };

    let space = Space::new(&k, &a);
    let oracle = HlsOracle::new(dev.clone());
    let original = oracle.synth(&k, &a, &Design::empty(&k));

    let nlpdse = cfg
        .engines
        .nlpdse
        .then(|| dse::run_nlp_dse(&k, &a, &dev, &cfg.dse, evaluator));
    let autodse = cfg
        .engines
        .autodse
        .then(|| baselines::run_autodse(&k, &a, &dev, &cfg.autodse));
    let harp = cfg
        .engines
        .harp
        .then(|| baselines::run_harp(&k, &a, &dev, &cfg.harp));

    KernelRow {
        name: name.to_string(),
        size,
        nl: k.n_loops(),
        nd: a.deps.nd(),
        space_size: space.size(),
        footprint_bytes: a.total_footprint,
        original_gflops: original.gflops(&a, &dev),
        nlpdse,
        autodse,
        harp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_campaign_completes() {
        let mut cfg = CampaignConfig::quick();
        cfg.kernels.truncate(3);
        cfg.harp.sweep_configs = 1_000;
        let r = run_campaign(&cfg);
        assert_eq!(r.rows.len(), 3);
        for row in &r.rows {
            assert!(row.nlpdse.is_some());
            assert!(row.autodse.is_some());
            assert!(row.harp.is_some());
            let n = row.nlpdse.as_ref().unwrap();
            assert!(n.best_gflops > 0.0, "{}", row.name);
        }
    }

    #[test]
    fn rows_preserve_order() {
        let mut cfg = CampaignConfig::quick();
        cfg.engines = Engines::nlp_only();
        let r = run_campaign(&cfg);
        let names: Vec<&str> = r.rows.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["gemm", "2mm", "bicg", "atax", "mvt"]);
    }
}
