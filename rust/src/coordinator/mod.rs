//! Campaign coordinator: runs the evaluation matrix (kernels × sizes ×
//! engines) across a thread pool and aggregates per-kernel rows for the
//! report generators.
//!
//! Scheduling model: every (kernel-instance, engine) pair is one
//! `Box<dyn Engine>` job on the pool — engines come from the
//! [`Registry`], so a newly registered engine joins campaigns without a
//! coordinator edit. A kernel's engines run concurrently with each
//! other and with every other kernel; a separate lightweight job per
//! kernel computes the static columns (space size, footprint, original
//! throughput).
//!
//! Threading model: PJRT handles are thread-affine, so when the XLA
//! path is enabled each job loads its *own* copy of the artifact
//! (compile-once-per-job, ~100 ms) — python never runs, and the
//! artifact never crosses threads.

pub mod pool;

use crate::baselines::{AutoDseOutcome, HarpOutcome};
use crate::benchmarks::{self, Size};
use crate::dse::{DseConfig, DseOutcome};
use crate::engine::{
    Engine, EngineTuning, Evaluator, Exploration, ExploreCtx, Explorer, Registry,
};
use crate::hls::{Device, HlsOracle};
use crate::ir::DType;
use crate::nlp::{BatchEvaluator, RustFeatureEvaluator};
use crate::poly::Analysis;
use crate::pragma::{Design, Space};
use crate::runtime::{default_artifact_dir, XlaEvaluator};
use pool::ThreadPool;

/// One campaign: which kernels, which engines, how to run them.
#[derive(Clone, Debug)]
pub struct CampaignConfig {
    /// Kernel instances (registry name or `.knl` path, with size).
    pub kernels: Vec<(String, Size)>,
    /// Precision for every registry kernel in the campaign.
    pub dtype: DType,
    /// Registry names of the engines to run per kernel instance.
    pub engines: Vec<String>,
    /// Thread-pool width for the (kernel, engine) jobs.
    pub threads: usize,
    /// Evaluate NLP candidates through the AOT XLA artifact.
    pub use_xla: bool,
    /// Per-engine campaign parameters, handed to every registry factory.
    pub tuning: EngineTuning,
    /// NLP-solver worker threads *per pool job*. The constructors pin
    /// the tuning to the serial path (`jobs = 1`) because the pool
    /// already saturates the host; this knob re-opens nesting without
    /// reaching into `tuning` — it overrides `tuning.dse.jobs` at run
    /// time in every campaign path (`None` keeps the tuning's value).
    /// Results are bit-identical for any value (the solver's
    /// deterministic reduction).
    pub solver_jobs: Option<usize>,
}

/// `engines` helper: owned names from a literal list.
pub fn engine_names(list: &[&str]) -> Vec<String> {
    list.iter().map(|s| s.to_string()).collect()
}

impl CampaignConfig {
    /// The paper's main comparison matrix: all kernels × {M, L}, f32.
    pub fn paper_autodse() -> CampaignConfig {
        let mut kernels = Vec::new();
        for name in benchmarks::ALL {
            if name == "cnn" {
                kernels.push((name.to_string(), Size::Medium));
                continue;
            }
            kernels.push((name.to_string(), Size::Medium));
            kernels.push((name.to_string(), Size::Large));
        }
        CampaignConfig {
            kernels,
            dtype: DType::F32,
            engines: engine_names(&["nlpdse", "autodse"]),
            threads: num_threads(),
            use_xla: false,
            tuning: serial_solver_tuning(EngineTuning::default()),
            solver_jobs: None,
        }
    }

    /// The HARP comparison: S+M, f64, HARP ladder (Section 7.4).
    pub fn paper_harp() -> CampaignConfig {
        let mut kernels = Vec::new();
        for name in benchmarks::ALL {
            if name == "cnn" {
                continue;
            }
            kernels.push((name.to_string(), Size::Small));
            kernels.push((name.to_string(), Size::Medium));
        }
        CampaignConfig {
            kernels,
            dtype: DType::F64,
            engines: engine_names(&["nlpdse", "harp"]),
            threads: num_threads(),
            use_xla: false,
            tuning: serial_solver_tuning(EngineTuning {
                dse: DseConfig {
                    ladder: DseConfig::harp_ladder(),
                    ..DseConfig::default()
                },
                ..EngineTuning::default()
            }),
            solver_jobs: None,
        }
    }

    /// A fast sanity scope (small sizes, a handful of kernels).
    pub fn quick() -> CampaignConfig {
        let kernels = ["gemm", "2mm", "bicg", "atax", "mvt"]
            .iter()
            .map(|n| (n.to_string(), Size::Small))
            .collect();
        CampaignConfig {
            kernels,
            dtype: DType::F32,
            engines: engine_names(&["nlpdse", "autodse", "harp"]),
            threads: num_threads(),
            use_xla: false,
            tuning: serial_solver_tuning(EngineTuning {
                harp: crate::baselines::HarpConfig {
                    sweep_configs: 5_000,
                    ..crate::baselines::HarpConfig::default()
                },
                ..EngineTuning::default()
            }),
            solver_jobs: None,
        }
    }

    /// The tuning each campaign job actually receives: `tuning` with
    /// [`solver_jobs`](CampaignConfig::solver_jobs) applied on top.
    pub fn effective_tuning(&self) -> EngineTuning {
        let mut t = self.tuning.clone();
        if let Some(j) = self.solver_jobs {
            t.dse.jobs = j.max(1);
        }
        t
    }
}

/// Campaign default: the pool's kernel×engine jobs already saturate the
/// host, so each job's NLP solver runs serially (`jobs = 1`) instead of
/// oversubscribing cores² — the CLI's `--jobs` opts back into nesting.
/// Results are identical either way (the solver's deterministic
/// reduction); only the scheduling changes.
fn serial_solver_tuning(mut t: EngineTuning) -> EngineTuning {
    t.dse.jobs = 1;
    t
}

/// Default pool width: host parallelism, capped at 16.
pub fn num_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// One kernel-instance row: static columns + one normalized
/// [`Exploration`] per engine (in campaign engine order).
#[derive(Clone, Debug)]
pub struct KernelRow {
    /// Kernel spec of the row.
    pub name: String,
    /// Problem size of the row.
    pub size: Size,
    /// Number of loops (`NL` column).
    pub nl: usize,
    /// Number of dependences (`ND` column).
    pub nd: usize,
    /// Count of valid designs in the pragma space.
    pub space_size: f64,
    /// Total array footprint, bytes (Table 8).
    pub footprint_bytes: u64,
    /// Throughput of the pragma-free design (the `Original` rows).
    pub original_gflops: f64,
    /// One normalized outcome per engine, in campaign engine order.
    pub explorations: Vec<Exploration>,
}

impl KernelRow {
    /// The outcome of a specific engine, by registry name.
    pub fn exploration(&self, engine: &str) -> Option<&Exploration> {
        self.explorations.iter().find(|e| e.engine == engine)
    }

    /// Legacy NLP-DSE detail (for the paper's table/figure generators).
    pub fn nlpdse(&self) -> Option<&DseOutcome> {
        self.explorations.iter().find_map(|e| e.as_nlpdse())
    }

    /// Legacy AutoDSE detail, if an `autodse` exploration ran.
    pub fn autodse(&self) -> Option<&AutoDseOutcome> {
        self.explorations.iter().find_map(|e| e.as_autodse())
    }

    /// Legacy HARP detail, if a `harp` exploration ran.
    pub fn harp(&self) -> Option<&HarpOutcome> {
        self.explorations.iter().find_map(|e| e.as_harp())
    }
}

/// All finished rows of a campaign, in configured kernel order.
#[derive(Clone, Debug, Default)]
pub struct CampaignResult {
    /// One row per kernel instance that resolved.
    pub rows: Vec<KernelRow>,
}

/// Static (engine-independent) columns of one kernel row.
#[derive(Clone, Debug)]
struct StaticInfo {
    nl: usize,
    nd: usize,
    space_size: f64,
    footprint_bytes: u64,
    original_gflops: f64,
}

fn static_info(name: &str, size: Size, dtype: DType) -> anyhow::Result<StaticInfo> {
    let k = benchmarks::lookup(name, size, dtype)?;
    let a = Analysis::new(&k);
    Ok(static_info_from(&k, &a))
}

fn static_info_from(k: &crate::ir::Kernel, a: &Analysis) -> StaticInfo {
    let dev = Device::u200();
    let space = Space::new(k, a);
    let original = HlsOracle::new(dev.clone()).synth(k, a, &Design::empty(k));
    StaticInfo {
        nl: k.n_loops(),
        nd: a.deps.nd(),
        space_size: space.size(),
        footprint_bytes: a.total_footprint,
        original_gflops: original.gflops(a, &dev),
    }
}

enum CampaignMsg {
    Stat(usize, StaticInfo),
    Expl(usize, usize, Exploration),
}

/// Run the campaign with the builtin engine registry. Third-party
/// engines join via [`run_campaign_with`].
pub fn run_campaign(cfg: &CampaignConfig) -> CampaignResult {
    run_campaign_with(&Registry::builtin(), cfg)
}

/// Run the campaign against a caller-supplied registry: one pool job
/// per kernel for the static columns, one `Box<dyn Engine>` pool job
/// per (kernel, engine) pair.
pub fn run_campaign_with(registry: &Registry, cfg: &CampaignConfig) -> CampaignResult {
    let pool = ThreadPool::new(cfg.threads);
    let (tx, rx) = std::sync::mpsc::channel::<CampaignMsg>();
    let n_kernels = cfg.kernels.len();

    for (idx, (name, size)) in cfg.kernels.iter().cloned().enumerate() {
        let tx = tx.clone();
        let dtype = cfg.dtype;
        pool.execute(move || match static_info(&name, size, dtype) {
            Ok(st) => {
                let _ = tx.send(CampaignMsg::Stat(idx, st));
            }
            // an unresolvable kernel drops its row (reported, not fatal —
            // the rest of the campaign proceeds)
            Err(err) => eprintln!("[campaign] skipping kernel `{name}`: {err:#}"),
        });
    }
    let tuning = cfg.effective_tuning();
    for (idx, (name, size)) in cfg.kernels.iter().cloned().enumerate() {
        for (eidx, ename) in cfg.engines.iter().enumerate() {
            let engine: Box<dyn Engine> = match registry.create(ename, &tuning) {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("[campaign] skipping: {err:#}");
                    continue;
                }
            };
            let tx = tx.clone();
            let name = name.clone();
            let dtype = cfg.dtype;
            let use_xla = cfg.use_xla;
            pool.execute(move || {
                let k = match benchmarks::lookup(&name, size, dtype) {
                    Ok(k) => k,
                    // report independently: for file-backed kernels this
                    // lookup re-reads the file and can fail even when the
                    // static-columns job succeeded (file changed between)
                    Err(err) => {
                        eprintln!(
                            "[campaign] {name}-{}: exploration skipped: {err:#}",
                            size.tag()
                        );
                        return;
                    }
                };
                let a = Analysis::new(&k);
                let dev = Device::u200();
                // each job gets its own evaluator (PJRT is thread-affine);
                // black-box engines skip the artifact compile entirely
                let xla = if use_xla && engine.uses_evaluator() {
                    XlaEvaluator::load(&default_artifact_dir()).ok()
                } else {
                    None
                };
                let evaluator: &dyn BatchEvaluator = match &xla {
                    Some(e) => e,
                    None => &RustFeatureEvaluator,
                };
                // model-driven engines share one symbolic bound model per
                // job; black-box engines (uses_evaluator = false) skip the
                // build entirely
                let bound = engine
                    .uses_evaluator()
                    .then(|| crate::model::sym::BoundModel::build(&k, &a, &dev));
                let ctx = ExploreCtx {
                    kernel: &k,
                    analysis: &a,
                    device: &dev,
                    evaluator,
                    bound: bound.as_ref(),
                };
                let _ = tx.send(CampaignMsg::Expl(idx, eidx, engine.explore(&ctx)));
            });
        }
    }
    drop(tx);

    let mut statics: Vec<Option<StaticInfo>> = vec![None; n_kernels];
    let mut expls: Vec<Vec<(usize, Exploration)>> = (0..n_kernels).map(|_| Vec::new()).collect();
    for msg in rx {
        match msg {
            CampaignMsg::Stat(i, s) => statics[i] = Some(s),
            CampaignMsg::Expl(i, e, x) => expls[i].push((e, x)),
        }
    }
    pool.join();

    let mut rows = Vec::new();
    for (i, (name, size)) in cfg.kernels.iter().enumerate() {
        let Some(st) = statics[i].take() else { continue };
        let mut es = std::mem::take(&mut expls[i]);
        es.sort_by_key(|(e, _)| *e);
        rows.push(KernelRow {
            name: name.clone(),
            size: *size,
            nl: st.nl,
            nd: st.nd,
            space_size: st.space_size,
            footprint_bytes: st.footprint_bytes,
            original_gflops: st.original_gflops,
            explorations: es.into_iter().map(|(_, x)| x).collect(),
        });
    }
    CampaignResult { rows }
}

/// One system-mode campaign: kernel instances sharing one device's
/// DSP/BRAM/LUT budget. Per-kernel front extractions are pure, so they
/// fan out across the pool and reassemble by index — the outcome is
/// identical to the sequential [`crate::system::solve_system`] path.
#[derive(Clone, Debug)]
pub struct SystemCampaignConfig {
    /// Kernel instances (registry names, with size).
    pub kernels: Vec<(String, Size)>,
    /// Precision for every kernel.
    pub dtype: DType,
    /// Pool width for the per-kernel front-solve jobs.
    pub threads: usize,
    /// Front extraction + allocation knobs (per-kernel solver `jobs`
    /// stays 1 by default — the pool already saturates the host).
    pub system: crate::system::SystemConfig,
}

impl SystemCampaignConfig {
    /// A fast two-kernel sanity scope.
    pub fn quick() -> SystemCampaignConfig {
        SystemCampaignConfig {
            kernels: vec![
                ("gemm".into(), Size::Small),
                ("bicg".into(), Size::Small),
            ],
            dtype: DType::F32,
            threads: num_threads(),
            system: crate::system::SystemConfig::default(),
        }
    }
}

/// Run a system campaign: one pool job per kernel computes its
/// epsilon-dominance front ([`crate::system::kernel_front`]), then the
/// budget allocation runs once over the reassembled fronts. Kernels
/// that fail to resolve are skipped with a report (their slot is
/// dropped, shrinking the system — same policy as [`run_campaign`]).
pub fn run_system_campaign(cfg: &SystemCampaignConfig) -> crate::system::SystemOutcome {
    let pool = ThreadPool::new(cfg.threads);
    let (tx, rx) = std::sync::mpsc::channel::<(usize, crate::system::KernelFront)>();
    for (idx, (name, size)) in cfg.kernels.iter().cloned().enumerate() {
        let tx = tx.clone();
        let dtype = cfg.dtype;
        let sys = cfg.system;
        pool.execute(move || {
            let k = match benchmarks::lookup(&name, size, dtype) {
                Ok(k) => k,
                Err(err) => {
                    eprintln!("[system] skipping kernel `{name}`: {err:#}");
                    return;
                }
            };
            let dev = Device::u200();
            let kf =
                crate::system::kernel_front(&k.name, &k, &dev, &sys, &crate::nlp::SymbolicEvaluator);
            let _ = tx.send((idx, kf));
        });
    }
    drop(tx);
    let mut slots: Vec<Option<crate::system::KernelFront>> = vec![None; cfg.kernels.len()];
    for (idx, kf) in rx {
        slots[idx] = Some(kf);
    }
    pool.join();
    let fronts: Vec<crate::system::KernelFront> = slots.into_iter().flatten().collect();
    crate::system::assemble(fronts, &Device::u200())
}

/// Process one kernel instance sequentially through the [`Explorer`]
/// facade (used for single-kernel flows; campaigns go through
/// [`run_campaign`]). Errors on unresolvable kernel specs (the facade
/// accepts registry names and `.knl` file paths alike).
pub fn run_one(cfg: &CampaignConfig, name: &str, size: Size) -> anyhow::Result<KernelRow> {
    let explorer = Explorer::kernel_dtype(name, size, cfg.dtype)?
        .evaluator(if cfg.use_xla {
            Evaluator::auto()
        } else {
            Evaluator::rust()
        })
        .tuning(cfg.effective_tuning());
    // static columns reuse the session's kernel + analysis (the exact
    // polyhedral analysis is the expensive static step)
    let st = static_info_from(explorer.kernel_ref(), explorer.analysis());
    let mut explorations = Vec::new();
    for ename in &cfg.engines {
        match explorer.run_engine(ename) {
            Ok(ex) => explorations.push(ex),
            Err(err) => eprintln!("[campaign] {name}-{}: {err:#}", size.tag()),
        }
    }
    Ok(KernelRow {
        name: name.to_string(),
        size,
        nl: st.nl,
        nd: st.nd,
        space_size: st.space_size,
        footprint_bytes: st.footprint_bytes,
        original_gflops: st.original_gflops,
        explorations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_campaign_completes() {
        let mut cfg = CampaignConfig::quick();
        cfg.kernels.truncate(3);
        cfg.tuning.harp.sweep_configs = 1_000;
        let r = run_campaign(&cfg);
        assert_eq!(r.rows.len(), 3);
        for row in &r.rows {
            // explorations arrive in campaign engine order
            let order: Vec<&str> = row.explorations.iter().map(|e| e.engine.as_str()).collect();
            assert_eq!(order, vec!["nlpdse", "autodse", "harp"], "{}", row.name);
            assert!(row.nlpdse().is_some());
            assert!(row.autodse().is_some());
            assert!(row.harp().is_some());
            let n = row.nlpdse().unwrap();
            assert!(n.best_gflops > 0.0, "{}", row.name);
        }
    }

    #[test]
    fn rows_preserve_order() {
        let mut cfg = CampaignConfig::quick();
        cfg.engines = engine_names(&["nlpdse"]);
        let r = run_campaign(&cfg);
        let names: Vec<&str> = r.rows.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["gemm", "2mm", "bicg", "atax", "mvt"]);
    }

    #[test]
    fn unknown_engine_is_skipped_not_fatal() {
        let mut cfg = CampaignConfig::quick();
        cfg.kernels.truncate(1);
        cfg.engines = engine_names(&["nlpdse", "definitely-not-an-engine"]);
        let r = run_campaign(&cfg);
        assert_eq!(r.rows.len(), 1);
        assert_eq!(r.rows[0].explorations.len(), 1);
        assert_eq!(r.rows[0].explorations[0].engine, "nlpdse");
    }

    #[test]
    fn third_party_engine_joins_campaign_via_custom_registry() {
        fn factory(_t: &EngineTuning) -> Box<dyn Engine> {
            Box::new(crate::engine::RandomSearchEngine::new(
                crate::engine::RandomConfig {
                    samples: 200,
                    synth_budget: 4,
                    ..Default::default()
                },
            ))
        }
        let mut reg = Registry::builtin();
        reg.register("my-search", factory);
        let mut cfg = CampaignConfig::quick();
        cfg.kernels.truncate(1);
        cfg.engines = engine_names(&["my-search"]);
        let r = run_campaign_with(&reg, &cfg);
        assert_eq!(r.rows.len(), 1);
        assert_eq!(r.rows[0].explorations.len(), 1);
        assert!(r.rows[0].explorations[0].best_gflops > 0.0);
    }

    #[test]
    fn run_one_matches_campaign_engines() {
        let mut cfg = CampaignConfig::quick();
        cfg.engines = engine_names(&["nlpdse", "random"]);
        let row = run_one(&cfg, "gemm", Size::Small).unwrap();
        assert_eq!(row.explorations.len(), 2);
        assert!(row.exploration("random").is_some());
        assert!(row.exploration("random").unwrap().best_gflops > 0.0);
    }

    #[test]
    fn solver_jobs_overrides_the_serial_pin_without_changing_results() {
        let mut cfg = CampaignConfig::quick();
        cfg.engines = engine_names(&["nlpdse"]);
        // the constructors pin the per-job solver serial...
        assert_eq!(cfg.effective_tuning().dse.jobs, 1);
        // ...and the knob overrides it without touching `tuning`
        cfg.solver_jobs = Some(2);
        assert_eq!(cfg.effective_tuning().dse.jobs, 2);
        assert_eq!(cfg.tuning.dse.jobs, 1, "tuning itself stays untouched");
        let par = run_one(&cfg, "atax", Size::Small).unwrap();
        cfg.solver_jobs = None;
        let ser = run_one(&cfg, "atax", Size::Small).unwrap();
        // deterministic reduction: nesting changes scheduling only
        assert_eq!(
            par.explorations[0].best_gflops,
            ser.explorations[0].best_gflops
        );
        assert_eq!(par.explorations[0].best, ser.explorations[0].best);
    }

    #[test]
    fn system_campaign_matches_the_sequential_path() {
        let mut cfg = SystemCampaignConfig::quick();
        cfg.system.cap = 64;
        cfg.system.front.max_points = 6;
        let pooled = run_system_campaign(&cfg);
        let kernels: Vec<(String, crate::ir::Kernel)> = cfg
            .kernels
            .iter()
            .map(|(n, s)| {
                let k = benchmarks::lookup(n, *s, cfg.dtype).unwrap();
                (k.name.clone(), k)
            })
            .collect();
        let seq = crate::system::solve_system(
            &kernels,
            &Device::u200(),
            &cfg.system,
            &crate::nlp::SymbolicEvaluator,
        );
        assert_eq!(pooled.kernels.len(), seq.kernels.len());
        for (a, b) in pooled.kernels.iter().zip(&seq.kernels) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.front.len(), b.front.len());
            for (x, y) in a.front.iter().zip(&b.front) {
                assert_eq!(x.design, y.design);
                assert_eq!(x.latency.to_bits(), y.latency.to_bits());
            }
        }
        assert_eq!(
            pooled.alloc.best.as_ref().map(|b| b.choice.clone()),
            seq.alloc.best.as_ref().map(|b| b.choice.clone())
        );
    }

    #[test]
    fn unknown_kernel_is_skipped_not_fatal_too() {
        // the old path panicked the worker thread; now the row is
        // dropped with a clean report and the campaign completes
        let mut cfg = CampaignConfig::quick();
        cfg.kernels = vec![
            ("gemm".into(), Size::Small),
            ("definitely-not-a-kernel".into(), Size::Small),
        ];
        cfg.engines = engine_names(&["nlpdse"]);
        let r = run_campaign(&cfg);
        let names: Vec<&str> = r.rows.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["gemm"]);
        // single-kernel flows surface the same clean error
        let err = run_one(&cfg, "definitely-not-a-kernel", Size::Small).unwrap_err();
        assert!(format!("{err:#}").contains("unknown kernel"), "{err:#}");
    }
}
