//! `nlp-dse` — leader entry point.
//!
//! The binary is self-contained after `make artifacts`: it loads the AOT
//! XLA artifacts directly (python never runs at DSE time) and drives the
//! campaign coordinator, the NLP solver, the simulated Merlin/Vitis
//! toolchain, and the report generators. Run `nlp-dse help` for usage.

fn main() {
    if let Err(e) = nlp_dse::cli::main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
