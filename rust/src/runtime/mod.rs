//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and runs
//! bulk lower-bound evaluation from the DSE hot path.
//!
//! Interchange is **HLO text** — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see `python/compile/aot.py` and /opt/xla-example).
//!
//! Python never runs here: the artifact is compiled once per process and
//! executed with f64 feature tensors encoded by `model::features`.

#[cfg(feature = "xla")]
use crate::model::Abi;
use crate::model::{self, DesignFeatures};
use crate::nlp::{BatchEvaluator, NlpProblem};
use crate::pragma::Design;
#[cfg(feature = "xla")]
use anyhow::Context;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// Default artifact directory (relative to the repo root / CWD).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("NLP_DSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// The XLA-backed batch evaluator. `Send + Sync` (the `BatchEvaluator`
/// contract, required by the parallel NLP solver's worker team): the
/// PJRT executable sits behind an internal mutex, so cross-thread use is
/// *safe* but executions serialize per evaluator — the coordinator still
/// instantiates one per job, which remains the performant layout.
///
/// Requires the `xla` cargo feature (the `xla` PJRT bindings are a
/// native-library dependency that is not always available); without it
/// this is a stub whose `load` fails cleanly and every caller falls
/// back to the in-process Rust evaluator.
#[cfg(feature = "xla")]
pub struct XlaEvaluator {
    exe: std::sync::Mutex<xla::PjRtLoadedExecutable>,
    /// Batch size the artifact was compiled for.
    pub batch: usize,
    /// Executions performed (perf accounting); see [`Self::executions`].
    executions: std::sync::atomic::AtomicU64,
}

// SAFETY: the PJRT handle types in the `xla` bindings carry raw FFI
// pointers and are not auto-`Send`/`Sync`, but the PJRT C API documents
// client/executable operations as thread-safe, and every use of `exe`
// here goes through the internal `Mutex` (one execution at a time, no
// thread-local PJRT state is relied upon). Required because
// `BatchEvaluator` is `Send + Sync` so one evaluator can serve the
// parallel solver's scoped worker team; the coordinator still creates
// one evaluator per job, which remains the performant layout.
#[cfg(feature = "xla")]
unsafe impl Send for XlaEvaluator {}
#[cfg(feature = "xla")]
unsafe impl Sync for XlaEvaluator {}

/// Stub built without the `xla` feature: `load` always fails, so the
/// Rust reference evaluator is used everywhere.
#[cfg(not(feature = "xla"))]
pub struct XlaEvaluator {
    /// Batch size the artifact was compiled for.
    pub batch: usize,
    /// Executions performed (perf accounting); see [`Self::executions`].
    executions: std::sync::atomic::AtomicU64,
}

impl XlaEvaluator {
    /// Artifact executions performed so far (perf accounting).
    pub fn executions(&self) -> u64 {
        self.executions.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(not(feature = "xla"))]
impl XlaEvaluator {
    /// Stub `load`: always fails (build with `--features xla` to enable).
    pub fn load(dir: &Path) -> Result<XlaEvaluator> {
        Err(anyhow!(
            "built without the `xla` cargo feature — cannot execute AOT artifacts \
             (artifact dir: {}); rebuild with `--features xla`",
            dir.display()
        ))
    }

    /// Stub evaluation: always fails (build with `--features xla`).
    pub fn eval_features(&self, _feats: &[DesignFeatures]) -> Result<Vec<(f64, f64)>> {
        Err(anyhow!("built without the `xla` cargo feature"))
    }
}

#[cfg(feature = "xla")]
impl XlaEvaluator {
    /// Load + compile `lat_bound.hlo.txt` from `dir`.
    pub fn load(dir: &Path) -> Result<XlaEvaluator> {
        let path = dir.join("lat_bound.hlo.txt");
        if !path.exists() {
            return Err(anyhow!(
                "artifact {} missing — run `make artifacts`",
                path.display()
            ));
        }
        let batch = read_abi_batch(&dir.join("abi.json")).unwrap_or(512);
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .context("parse HLO text")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile artifact")?;
        Ok(XlaEvaluator {
            exe: std::sync::Mutex::new(exe),
            batch,
            executions: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Evaluate encoded designs; input is chunked/padded to the artifact's
    /// batch size. Returns `(latency_lb, dsp)` per design.
    pub fn eval_features(&self, feats: &[DesignFeatures]) -> Result<Vec<(f64, f64)>> {
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(self.batch) {
            // flatten + zero-pad
            let mut loops = vec![0f64; self.batch * Abi::LOOPS_LEN];
            let mut units = vec![0f64; self.batch * Abi::UNITS_LEN];
            for (i, f) in chunk.iter().enumerate() {
                loops[i * Abi::LOOPS_LEN..(i + 1) * Abi::LOOPS_LEN]
                    .copy_from_slice(&f.loops);
                units[i * Abi::UNITS_LEN..(i + 1) * Abi::UNITS_LEN]
                    .copy_from_slice(&f.units);
            }
            let l_lit = xla::Literal::vec1(&loops).reshape(&[
                self.batch as i64,
                Abi::UNITS as i64,
                Abi::LOOPS as i64,
                Abi::F as i64,
            ])?;
            let u_lit = xla::Literal::vec1(&units).reshape(&[
                self.batch as i64,
                Abi::UNITS as i64,
                Abi::G as i64,
            ])?;
            let result = {
                // PJRT execution serializes behind the lock; one evaluator
                // per worker (the coordinator's layout) never contends
                let exe = self.exe.lock().unwrap();
                exe.execute::<xla::Literal>(&[l_lit, u_lit])?[0][0].to_literal_sync()?
            };
            self.executions
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            // return_tuple=True → 1-tuple of f64[B,2]
            let tuple = result.to_tuple1()?;
            let values = tuple.to_vec::<f64>()?;
            for (i, _) in chunk.iter().enumerate() {
                out.push((values[i * 2], values[i * 2 + 1]));
            }
        }
        Ok(out)
    }
}

impl BatchEvaluator for XlaEvaluator {
    fn eval_batch(&self, p: &NlpProblem, designs: &[Design]) -> Vec<(f64, f64)> {
        // encode; designs that overflow the ABI fall back to the precise
        // Rust model (identical lower-bound semantics)
        let mut feats = Vec::with_capacity(designs.len());
        let mut fallback: Vec<(usize, (f64, f64))> = Vec::new();
        let mut idx_map = Vec::with_capacity(designs.len());
        for (i, d) in designs.iter().enumerate() {
            match model::encode_design(p.kernel, p.analysis, p.device, d) {
                Some(f) => {
                    idx_map.push(i);
                    feats.push(f);
                }
                None => {
                    let r = model::evaluate(p.kernel, p.analysis, p.device, d);
                    fallback.push((i, (r.total_cycles, r.dsp)));
                }
            }
        }
        let mut out = vec![(0f64, 0f64); designs.len()];
        match self.eval_features(&feats) {
            Ok(vals) => {
                for (slot, v) in idx_map.into_iter().zip(vals) {
                    out[slot] = v;
                }
            }
            Err(_) => {
                // degraded mode: evaluate in-process
                for (slot, d) in idx_map.iter().zip(feats.iter()) {
                    out[*slot] = model::eval_features(d);
                }
            }
        }
        for (i, v) in fallback {
            out[i] = v;
        }
        out
    }
}

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn read_abi_batch(path: &Path) -> Option<usize> {
    let text = std::fs::read_to_string(path).ok()?;
    let idx = text.find("\"batch\"")?;
    let rest = &text[idx + 7..];
    let colon = rest.find(':')?;
    let num: String = rest[colon + 1..]
        .trim_start()
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    num.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abi_batch_parser() {
        let dir = std::env::temp_dir().join("nlpdse-abi-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("abi.json");
        std::fs::write(&p, "{\n  \"batch\": 512,\n  \"units\": 16\n}").unwrap();
        assert_eq!(read_abi_batch(&p), Some(512));
    }

    #[test]
    fn load_fails_cleanly_without_artifacts() {
        let r = XlaEvaluator::load(Path::new("/nonexistent-dir"));
        assert!(r.is_err());
    }
}
