//! Kernel IR: loops, statements, arrays, and the finalized metadata the
//! analyses consume.

use super::expr::AffineExpr;
use super::{ArrayId, LoopId, StmtId};
use std::collections::BTreeMap;

/// Scalar element type of a kernel's arrays. The paper evaluates f32
/// against AutoDSE (Section 7.1) and f64 against HARP (Section 7.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float (the paper's main precision).
    F32,
    /// 64-bit IEEE float (the HARP comparison, Section 7.4).
    F64,
}

impl DType {
    /// Bit width of one element.
    pub fn bits(self) -> u64 {
        match self {
            DType::F32 => 32,
            DType::F64 => 64,
        }
    }
    /// Lowercase type name (`f32`/`f64`).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }
    /// Inverse of [`Self::name`] (the `.knl` frontend's dtype token).
    pub fn from_name(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            _ => None,
        }
    }
}

/// Transfer direction of an array w.r.t. off-chip DRAM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrayDir {
    /// Read-only input (live-in).
    In,
    /// Write-only output (live-out).
    Out,
    /// Read and written (live-in + live-out).
    InOut,
    /// Intermediate produced and consumed inside the kernel; Merlin still
    /// allocates it in DRAM unless it is fully cached on-chip.
    Temp,
}

impl ArrayDir {
    /// Array must be transferred in from DRAM.
    pub fn is_live_in(self) -> bool {
        matches!(self, ArrayDir::In | ArrayDir::InOut)
    }
    /// Array must be transferred back to DRAM.
    pub fn is_live_out(self) -> bool {
        matches!(self, ArrayDir::Out | ArrayDir::InOut)
    }
    /// The `.knl` frontend's direction keyword.
    pub fn word(self) -> &'static str {
        match self {
            ArrayDir::In => "in",
            ArrayDir::Out => "out",
            ArrayDir::InOut => "inout",
            ArrayDir::Temp => "temp",
        }
    }
    /// Inverse of [`Self::word`].
    pub fn from_word(s: &str) -> Option<ArrayDir> {
        match s {
            "in" => Some(ArrayDir::In),
            "out" => Some(ArrayDir::Out),
            "inout" => Some(ArrayDir::InOut),
            "temp" => Some(ArrayDir::Temp),
            _ => None,
        }
    }
}

/// One declared array.
#[derive(Clone, Debug)]
pub struct Array {
    /// Dense id (declaration order).
    pub id: ArrayId,
    /// Array identifier.
    pub name: String,
    /// Constant extents, outermost first.
    pub dims: Vec<u64>,
    /// Transfer direction w.r.t. off-chip DRAM.
    pub dir: ArrayDir,
}

impl Array {
    /// Number of elements.
    pub fn elements(&self) -> u64 {
        self.dims.iter().product()
    }
    /// Footprint in bytes for the kernel dtype.
    pub fn footprint_bytes(&self, dtype: DType) -> u64 {
        self.elements() * dtype.bits() / 8
    }
}

/// Scalar n-ary operation kinds (Definition B.1 normalizes bodies to one
/// operation per statement; we keep the per-iteration op multiset instead,
/// which is equivalent for latency/resource purposes and far terser).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpKind {
    /// Floating add.
    Add,
    /// Floating subtract.
    Sub,
    /// Floating multiply.
    Mul,
    /// Floating divide.
    Div,
}

impl OpKind {
    /// Every op kind, in a stable order.
    pub const ALL: [OpKind; 4] = [OpKind::Add, OpKind::Sub, OpKind::Mul, OpKind::Div];
    /// C operator spelling (`+`, `-`, `*`, `/`).
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Add => "+",
            OpKind::Sub => "-",
            OpKind::Mul => "*",
            OpKind::Div => "/",
        }
    }
    /// The `.knl` frontend's op keyword.
    pub fn word(self) -> &'static str {
        match self {
            OpKind::Add => "add",
            OpKind::Sub => "sub",
            OpKind::Mul => "mul",
            OpKind::Div => "div",
        }
    }
    /// Inverse of [`Self::word`].
    pub fn from_word(s: &str) -> Option<OpKind> {
        match s {
            "add" => Some(OpKind::Add),
            "sub" => Some(OpKind::Sub),
            "mul" => Some(OpKind::Mul),
            "div" => Some(OpKind::Div),
            _ => None,
        }
    }
}

/// An affine array access `array[indices...]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Access {
    /// Accessed array.
    pub array: ArrayId,
    /// One affine index per dimension.
    pub indices: Vec<AffineExpr>,
}

impl Access {
    /// Access to `array` at `indices`.
    pub fn new(array: ArrayId, indices: Vec<AffineExpr>) -> Access {
        Access { array, indices }
    }
}

/// A statement: one loop-body assignment with its access summary and the
/// multiset of scalar ops one iteration performs.
#[derive(Clone, Debug)]
pub struct Stmt {
    /// Dense id (creation order).
    pub id: StmtId,
    /// Statement label (`S0`, `S1`, …).
    pub name: String,
    /// Written accesses (at least one).
    pub writes: Vec<Access>,
    /// Read accesses.
    pub reads: Vec<Access>,
    /// `(op, count)` per iteration; e.g. `tmp += alpha*A*B` is
    /// `[(Mul, 2), (Add, 1)]`.
    pub ops: Vec<(OpKind, u32)>,
    /// Length (in op latencies) of the statement's internal critical path as
    /// a chain of ops, e.g. `alpha*A*B + tmp`: Mul→Mul→Add. Defaults to the
    /// full op chain (all ops sequential); kernels with known internal
    /// parallelism may override via the builder.
    pub chain: Vec<OpKind>,
}

impl Stmt {
    /// The conservative all-sequential internal chain: every op of the
    /// multiset in entry order (`a ⊕ b ⊕ c` as a pure chain). This is what
    /// [`super::KernelBuilder::stmt`] and the `.knl` frontend default to
    /// when no explicit `chain` is given.
    pub fn default_chain(ops: &[(OpKind, u32)]) -> Vec<OpKind> {
        ops.iter()
            .flat_map(|&(o, c)| std::iter::repeat(o).take(c as usize))
            .collect()
    }

    /// Per-iteration count of `op`.
    pub fn op_count(&self, op: OpKind) -> u32 {
        self.ops
            .iter()
            .filter(|(o, _)| *o == op)
            .map(|(_, c)| *c)
            .sum()
    }
    /// Total flop count of one iteration (all four kinds count as 1 flop,
    /// matching PolyBench's GF/s accounting).
    pub fn flops(&self) -> u64 {
        self.ops.iter().map(|(_, c)| *c as u64).sum()
    }
}

/// One node of the summary AST.
#[derive(Clone, Debug)]
pub enum Node {
    /// A (possibly nested) loop.
    Loop(Loop),
    /// A straight-line statement.
    Stmt(Stmt),
}

/// A `for` loop with half-open affine bounds `[lb, ub)` and unit stride
/// (PolyOpt-HLS restriction; negative strides are excluded — the paper drops
/// `ludcmp`/`deriche`/`nussinov` for the same reason).
#[derive(Clone, Debug)]
pub struct Loop {
    /// Dense id (creation order).
    pub id: LoopId,
    /// Iterator identifier.
    pub name: String,
    /// Lower bound (inclusive), affine over enclosing iterators.
    pub lb: AffineExpr,
    /// Upper bound (exclusive), affine over enclosing iterators.
    pub ub: AffineExpr,
    /// Loops and statements in syntactic order.
    pub body: Vec<Node>,
}

/// Finalized per-loop metadata.
#[derive(Clone, Debug)]
pub struct LoopMeta {
    /// The loop this metadata describes.
    pub id: LoopId,
    /// Iterator identifier.
    pub name: String,
    /// Directly enclosing loop, if any.
    pub parent: Option<LoopId>,
    /// 0 for top-level (nest root) loops.
    pub depth: u32,
    /// The top-level loop this one lives under (itself if top-level).
    pub nest_root: LoopId,
    /// Statements iterated by this loop (transitively).
    pub stmts: Vec<StmtId>,
    /// Direct child loops.
    pub children: Vec<LoopId>,
    /// True when the loop body is straight-line (no loops inside).
    pub innermost: bool,
}

/// Finalized per-statement metadata.
#[derive(Clone, Debug)]
pub struct StmtMeta {
    /// The statement this metadata describes.
    pub id: StmtId,
    /// Enclosing loops, outermost first.
    pub nest: Vec<LoopId>,
}

/// A finalized kernel.
#[derive(Clone, Debug)]
pub struct Kernel {
    /// Kernel name.
    pub name: String,
    /// Scalar element type of every array.
    pub dtype: DType,
    /// Declared arrays, by id.
    pub arrays: Vec<Array>,
    /// Top-level loop nests, in syntactic order.
    pub roots: Vec<Node>,
    /// Per-loop metadata, by id.
    pub loops: Vec<LoopMeta>,
    /// Per-statement metadata, by id.
    pub stmts_meta: Vec<StmtMeta>,
    stmt_table: Vec<Stmt>,
    loop_table: Vec<Loop>, // bounds + names snapshot (bodies not duplicated)
}

impl Kernel {
    /// Build metadata from a raw tree. Called by [`super::KernelBuilder`].
    pub fn finalize(name: &str, dtype: DType, arrays: Vec<Array>, roots: Vec<Node>) -> Kernel {
        let mut loops: BTreeMap<u32, LoopMeta> = BTreeMap::new();
        let mut stmts_meta: Vec<StmtMeta> = Vec::new();
        let mut stmt_table: Vec<Stmt> = Vec::new();
        let mut loop_table: BTreeMap<u32, Loop> = BTreeMap::new();

        fn walk(
            node: &Node,
            path: &mut Vec<LoopId>,
            loops: &mut BTreeMap<u32, LoopMeta>,
            stmts_meta: &mut Vec<StmtMeta>,
            stmt_table: &mut Vec<Stmt>,
            loop_table: &mut BTreeMap<u32, Loop>,
        ) {
            match node {
                Node::Loop(l) => {
                    let parent = path.last().copied();
                    let nest_root = path.first().copied().unwrap_or(l.id);
                    let innermost = l.body.iter().all(|n| matches!(n, Node::Stmt(_)));
                    loops.insert(
                        l.id.0,
                        LoopMeta {
                            id: l.id,
                            name: l.name.clone(),
                            parent,
                            depth: path.len() as u32,
                            nest_root,
                            stmts: vec![],
                            children: vec![],
                            innermost,
                        },
                    );
                    if let Some(p) = parent {
                        loops.get_mut(&p.0).unwrap().children.push(l.id);
                    }
                    loop_table.insert(
                        l.id.0,
                        Loop {
                            id: l.id,
                            name: l.name.clone(),
                            lb: l.lb.clone(),
                            ub: l.ub.clone(),
                            body: vec![],
                        },
                    );
                    path.push(l.id);
                    for child in &l.body {
                        walk(child, path, loops, stmts_meta, stmt_table, loop_table);
                    }
                    path.pop();
                }
                Node::Stmt(s) => {
                    stmts_meta.push(StmtMeta {
                        id: s.id,
                        nest: path.clone(),
                    });
                    for l in path.iter() {
                        loops.get_mut(&l.0).unwrap().stmts.push(s.id);
                    }
                    stmt_table.push(s.clone());
                }
            }
        }

        let mut path = Vec::new();
        for root in &roots {
            walk(
                root,
                &mut path,
                &mut loops,
                &mut stmts_meta,
                &mut stmt_table,
                &mut loop_table,
            );
        }
        stmts_meta.sort_by_key(|s| s.id);
        stmt_table.sort_by_key(|s| s.id);

        let n_loops = loops.len() as u32;
        // Ids must be dense (builder assigns them in creation order).
        for i in 0..n_loops {
            assert!(loops.contains_key(&i), "non-dense loop ids");
        }

        Kernel {
            name: name.to_string(),
            dtype,
            arrays,
            roots,
            loops: (0..n_loops).map(|i| loops.remove(&i).unwrap()).collect(),
            stmts_meta,
            stmt_table,
            loop_table: (0..n_loops).map(|i| loop_table.remove(&i).unwrap()).collect(),
        }
    }

    /// Number of loops.
    pub fn n_loops(&self) -> usize {
        self.loops.len()
    }
    /// Number of statements.
    pub fn n_stmts(&self) -> usize {
        self.stmt_table.len()
    }

    /// Metadata of loop `l`.
    pub fn loop_meta(&self, l: LoopId) -> &LoopMeta {
        &self.loops[l.0 as usize]
    }
    /// `[lb, ub)` bounds of loop `l`.
    pub fn loop_bounds(&self, l: LoopId) -> (&AffineExpr, &AffineExpr) {
        let lp = &self.loop_table[l.0 as usize];
        (&lp.lb, &lp.ub)
    }
    /// Iterator name of loop `l`.
    pub fn loop_name(&self, l: LoopId) -> &str {
        &self.loop_table[l.0 as usize].name
    }
    /// Statement `s`.
    pub fn stmt(&self, s: StmtId) -> &Stmt {
        &self.stmt_table[s.0 as usize]
    }
    /// Metadata of statement `s`.
    pub fn stmt_meta(&self, s: StmtId) -> &StmtMeta {
        &self.stmts_meta[s.0 as usize]
    }
    /// Array `a`.
    pub fn array(&self, a: ArrayId) -> &Array {
        &self.arrays[a.0 as usize]
    }
    /// Array with the given name, if any.
    pub fn array_by_name(&self, name: &str) -> Option<&Array> {
        self.arrays.iter().find(|a| a.name == name)
    }

    /// Top-level loops (the kernel's loop nests), in syntactic order.
    pub fn nest_roots(&self) -> Vec<LoopId> {
        self.loops
            .iter()
            .filter(|m| m.parent.is_none())
            .map(|m| m.id)
            .collect()
    }

    /// All loops in the nest rooted at `root`, pre-order.
    pub fn nest_loops(&self, root: LoopId) -> Vec<LoopId> {
        let mut out = vec![root];
        let mut i = 0;
        while i < out.len() {
            let cur = out[i];
            for &c in &self.loop_meta(cur).children {
                out.push(c);
            }
            i += 1;
        }
        out.sort();
        out
    }

    /// The chain of loops from the nest root down to and including `l`.
    pub fn loop_path(&self, l: LoopId) -> Vec<LoopId> {
        let mut path = vec![l];
        let mut cur = l;
        while let Some(p) = self.loop_meta(cur).parent {
            path.push(p);
            cur = p;
        }
        path.reverse();
        path
    }

    /// Iterator over all statements.
    pub fn stmts(&self) -> impl Iterator<Item = &Stmt> {
        self.stmt_table.iter()
    }

    /// All accesses (reads + writes) of a statement.
    pub fn stmt_accesses(&self, s: StmtId) -> impl Iterator<Item = (&Access, bool)> {
        let st = self.stmt(s);
        st.writes
            .iter()
            .map(|a| (a, true))
            .chain(st.reads.iter().map(|a| (a, false)))
    }

    /// Whether loop `inner` is (transitively) inside loop `outer`.
    pub fn is_under(&self, inner: LoopId, outer: LoopId) -> bool {
        let mut cur = inner;
        while let Some(p) = self.loop_meta(cur).parent {
            if p == outer {
                return true;
            }
            cur = p;
        }
        false
    }

    /// Deep structural comparison against another kernel: name, dtype,
    /// arrays (name/dims/direction), and the full node tree including
    /// loop bounds, statement accesses, op multisets, and chains. Ids
    /// are compared too, but both sides being finalized pre-order
    /// kernels, they agree iff the trees agree.
    ///
    /// Returns `None` when structurally identical, or a human-readable
    /// description of the **first** difference — the `.knl` round-trip
    /// invariant (`parse(pretty(k)) ≡ k`) is asserted through this.
    pub fn structural_diff(&self, other: &Kernel) -> Option<String> {
        if self.name != other.name {
            return Some(format!("kernel name: `{}` vs `{}`", self.name, other.name));
        }
        if self.dtype != other.dtype {
            return Some(format!(
                "dtype: {} vs {}",
                self.dtype.name(),
                other.dtype.name()
            ));
        }
        if self.arrays.len() != other.arrays.len() {
            return Some(format!(
                "array count: {} vs {}",
                self.arrays.len(),
                other.arrays.len()
            ));
        }
        for (a, b) in self.arrays.iter().zip(&other.arrays) {
            if a.name != b.name || a.dims != b.dims || a.dir != b.dir || a.id != b.id {
                return Some(format!(
                    "array {}: {:?}[{:?}] {} vs {:?}[{:?}] {}",
                    a.id,
                    a.name,
                    a.dims,
                    a.dir.word(),
                    b.name,
                    b.dims,
                    b.dir.word()
                ));
            }
        }
        if self.roots.len() != other.roots.len() {
            return Some(format!(
                "top-level nest count: {} vs {}",
                self.roots.len(),
                other.roots.len()
            ));
        }
        for (i, (a, b)) in self.roots.iter().zip(&other.roots).enumerate() {
            if let Some(d) = node_diff(a, b, &format!("nest #{i}")) {
                return Some(d);
            }
        }
        None
    }

    /// Render the summary AST in constructor notation, e.g.
    /// `Loop_i(Loop_j1(S1), Loop_j2(S2, S3))` (Section 3.1).
    pub fn summary_ast(&self) -> String {
        fn walk(k: &Kernel, n: &Node, out: &mut String) {
            match n {
                Node::Loop(l) => {
                    out.push_str(&format!("Loop_{}(", k.loop_name(l.id)));
                    for (i, c) in l.body.iter().enumerate() {
                        if i > 0 {
                            out.push_str(", ");
                        }
                        walk(k, c, out);
                    }
                    out.push(')');
                }
                Node::Stmt(s) => out.push_str(&s.name),
            }
        }
        let mut out = String::new();
        for (i, r) in self.roots.iter().enumerate() {
            if i > 0 {
                out.push_str("; ");
            }
            walk(self, r, &mut out);
        }
        out
    }
}

/// First structural difference between two summary-AST nodes, or `None`.
fn node_diff(a: &Node, b: &Node, path: &str) -> Option<String> {
    match (a, b) {
        (Node::Loop(x), Node::Loop(y)) => {
            if x.id != y.id || x.name != y.name {
                return Some(format!(
                    "{path}: loop {}/`{}` vs {}/`{}`",
                    x.id, x.name, y.id, y.name
                ));
            }
            let path = format!("{path}.{}", x.name);
            if x.lb != y.lb || x.ub != y.ub {
                return Some(format!(
                    "{path}: bounds [{}, {}) vs [{}, {})",
                    x.lb, x.ub, y.lb, y.ub
                ));
            }
            if x.body.len() != y.body.len() {
                return Some(format!(
                    "{path}: body length {} vs {}",
                    x.body.len(),
                    y.body.len()
                ));
            }
            x.body
                .iter()
                .zip(&y.body)
                .find_map(|(c, d)| node_diff(c, d, &path))
        }
        (Node::Stmt(x), Node::Stmt(y)) => {
            if x.id != y.id || x.name != y.name {
                return Some(format!(
                    "{path}: stmt {}/`{}` vs {}/`{}`",
                    x.id, x.name, y.id, y.name
                ));
            }
            let path = format!("{path}.{}", x.name);
            if x.writes != y.writes {
                return Some(format!("{path}: writes differ"));
            }
            if x.reads != y.reads {
                return Some(format!("{path}: reads differ"));
            }
            if x.ops != y.ops {
                return Some(format!("{path}: ops {:?} vs {:?}", x.ops, y.ops));
            }
            if x.chain != y.chain {
                return Some(format!("{path}: chain {:?} vs {:?}", x.chain, y.chain));
            }
            None
        }
        (Node::Loop(x), Node::Stmt(y)) => {
            Some(format!("{path}: loop `{}` vs stmt `{}`", x.name, y.name))
        }
        (Node::Stmt(x), Node::Loop(y)) => {
            Some(format!("{path}: stmt `{}` vs loop `{}`", x.name, y.name))
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::ir::KernelBuilder;

    #[test]
    fn finalize_metadata_2mm_shape() {
        let k = crate::benchmarks::kernel_2mm(180, 190, 210, 220, super::DType::F32);
        assert_eq!(k.n_loops(), 6);
        assert_eq!(k.n_stmts(), 4);
        assert_eq!(k.nest_roots().len(), 2);
        // Loop2 (k1) is innermost of nest 0
        let nest0 = k.nest_loops(k.nest_roots()[0]);
        assert_eq!(nest0.len(), 3);
        let ast = k.summary_ast();
        assert!(ast.starts_with("Loop_i1(Loop_j1(S0, Loop_k1(S1)))"), "{ast}");
    }

    #[test]
    fn loop_path_and_is_under() {
        let k = crate::benchmarks::kernel_2mm(18, 19, 21, 22, super::DType::F32);
        let roots = k.nest_roots();
        let nest0 = k.nest_loops(roots[0]);
        let innermost = *nest0.last().unwrap();
        assert!(k.is_under(innermost, roots[0]));
        assert!(!k.is_under(roots[0], innermost));
        let path = k.loop_path(innermost);
        assert_eq!(path.len(), 3);
        assert_eq!(path[0], roots[0]);
    }

    #[test]
    fn builder_smoke_minimal() {
        use super::*;
        let mut kb = KernelBuilder::new("mini", DType::F32);
        let a = kb.array("a", &[8], ArrayDir::Out);
        let b = kb.array("b", &[8], ArrayDir::In);
        kb.for_const("i", 0, 8, |kb, i| {
            kb.stmt(
                "S0",
                vec![kb.at(a, &[kb.v(i)])],
                vec![kb.at(b, &[kb.v(i)])],
                &[(OpKind::Mul, 1)],
            );
        });
        let k = kb.finish();
        assert_eq!(k.n_loops(), 1);
        assert_eq!(k.n_stmts(), 1);
        assert_eq!(k.stmt(StmtId(0)).flops(), 1);
        assert_eq!(k.summary_ast(), "Loop_i(S0)");
    }
}
