//! Affine loop-nest intermediate representation.
//!
//! The paper restricts its input class to *polyhedral programs*: static
//! control flow, affine loop bounds, affine array accesses, straight-line
//! loop bodies with one n-ary operation per statement (Section 4.2 /
//! Definition B.1). This IR captures exactly that class:
//!
//! * a [`Kernel`] is a forest of [`Node`]s (loops and statements),
//! * every [`Loop`] has bounds that are either constants or affine
//!   expressions of *outer* loop iterators ([`AffineExpr`]),
//! * every [`Stmt`] carries its reads/writes as affine [`Access`]es and the
//!   multiset of scalar operations one iteration performs.
//!
//! The summary-AST of Section 3.1 is this tree; `poly` derives the PV-vector
//! ingredients (trip counts, dependences) from it, and `model` instantiates
//! the latency formula template over it.

pub mod build;
pub mod expr;
pub mod kernel;

pub use build::KernelBuilder;
pub use expr::AffineExpr;
pub use kernel::{Access, Array, ArrayDir, DType, Kernel, Loop, Node, OpKind, Stmt};

/// Identifies a loop within one kernel (dense, assigned in pre-order by
/// [`Kernel::finalize`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LoopId(pub u32);

/// Identifies a statement within one kernel (dense, pre-order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StmtId(pub u32);

/// Identifies an array within one kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ArrayId(pub u32);

impl std::fmt::Display for LoopId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}", self.0)
    }
}
impl std::fmt::Display for StmtId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "S{}", self.0)
    }
}
impl std::fmt::Display for ArrayId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "A{}", self.0)
    }
}
