//! Ergonomic builder for defining kernels in Rust (the PolyBench suite in
//! `benchmarks/` is written against this API).
//!
//! ```ignore
//! let mut kb = KernelBuilder::new("gemm", DType::F32);
//! let c = kb.array("C", &[ni, nj], ArrayDir::InOut);
//! let a = kb.array("A", &[ni, nk], ArrayDir::In);
//! let b = kb.array("B", &[nk, nj], ArrayDir::In);
//! kb.for_const("i", 0, ni, |kb, i| {
//!     kb.for_const("j", 0, nj, |kb, j| {
//!         kb.stmt("S0", vec![kb.at(c, &[kb.v(i), kb.v(j)])],
//!                 vec![kb.at(c, &[kb.v(i), kb.v(j)])], &[(OpKind::Mul, 1)]);
//!         kb.for_const("k", 0, nk, |kb, k| {
//!             kb.stmt("S1", /* C[i][j] += A[i][k]*B[k][j] */ ...);
//!         });
//!     });
//! });
//! let kernel = kb.finish();
//! ```

use super::expr::AffineExpr;
use super::kernel::{Access, Array, ArrayDir, DType, Kernel, Loop, Node, OpKind, Stmt};
use super::{ArrayId, LoopId, StmtId};

/// Incremental kernel constructor: declare arrays, nest loops with
/// closures, add statements, then [`Self::finish`].
pub struct KernelBuilder {
    name: String,
    dtype: DType,
    arrays: Vec<Array>,
    next_loop: u32,
    next_stmt: u32,
    /// Stack of open loops; `frames[0]` collects top-level nodes.
    frames: Vec<Vec<Node>>,
    open: Vec<(LoopId, String, AffineExpr, AffineExpr)>,
}

impl KernelBuilder {
    /// Start a kernel named `name` with element type `dtype`.
    pub fn new(name: &str, dtype: DType) -> KernelBuilder {
        KernelBuilder {
            name: name.to_string(),
            dtype,
            arrays: Vec::new(),
            next_loop: 0,
            next_stmt: 0,
            frames: vec![Vec::new()],
            open: Vec::new(),
        }
    }

    /// Declare an array.
    pub fn array(&mut self, name: &str, dims: &[u64], dir: ArrayDir) -> ArrayId {
        let id = ArrayId(self.arrays.len() as u32);
        self.arrays.push(Array {
            id,
            name: name.to_string(),
            dims: dims.to_vec(),
            dir,
        });
        id
    }

    /// Open a constant-bound loop `for name in [lb, ub)` and build its body
    /// inside `f` (which receives the fresh [`LoopId`]).
    pub fn for_const(
        &mut self,
        name: &str,
        lb: i64,
        ub: i64,
        f: impl FnOnce(&mut KernelBuilder, LoopId),
    ) -> LoopId {
        self.for_expr(name, AffineExpr::constant(lb), AffineExpr::constant(ub), f)
    }

    /// Open a loop with affine bounds (may reference enclosing loop ids).
    pub fn for_expr(
        &mut self,
        name: &str,
        lb: AffineExpr,
        ub: AffineExpr,
        f: impl FnOnce(&mut KernelBuilder, LoopId),
    ) -> LoopId {
        let id = LoopId(self.next_loop);
        self.next_loop += 1;
        // Bounds may only reference loops that are currently open.
        for dep in lb.loops().chain(ub.loops()) {
            assert!(
                self.open.iter().any(|(l, ..)| *l == dep),
                "loop bound references non-enclosing loop {dep}"
            );
        }
        self.open.push((id, name.to_string(), lb, ub));
        self.frames.push(Vec::new());
        f(self, id);
        let body = self.frames.pop().unwrap();
        let (id2, name2, lb2, ub2) = self.open.pop().unwrap();
        debug_assert_eq!(id, id2);
        self.frames.last_mut().unwrap().push(Node::Loop(Loop {
            id,
            name: name2,
            lb: lb2,
            ub: ub2,
            body,
        }));
        id
    }

    /// Add a statement to the current loop body. `ops` is the per-iteration
    /// op multiset; the internal dependency chain defaults to all ops in
    /// sequence (`chain = expanded ops`), which is the conservative critical
    /// path for `a ⊕ b ⊕ c` expressions.
    pub fn stmt(
        &mut self,
        name: &str,
        writes: Vec<Access>,
        reads: Vec<Access>,
        ops: &[(OpKind, u32)],
    ) -> StmtId {
        self.stmt_with_chain(name, writes, reads, ops, Stmt::default_chain(ops))
    }

    /// Like [`Self::stmt`] but with an explicit internal op chain (for
    /// statements whose expression tree is wider than a pure chain, e.g.
    /// `(a*b) + (c*d)` has chain Mul→Add, not Mul→Mul→Add).
    pub fn stmt_with_chain(
        &mut self,
        name: &str,
        writes: Vec<Access>,
        reads: Vec<Access>,
        ops: &[(OpKind, u32)],
        chain: Vec<OpKind>,
    ) -> StmtId {
        assert!(!self.open.is_empty(), "statement outside any loop");
        let id = StmtId(self.next_stmt);
        self.next_stmt += 1;
        self.frames.last_mut().unwrap().push(Node::Stmt(Stmt {
            id,
            name: name.to_string(),
            writes,
            reads,
            ops: ops.to_vec(),
            chain,
        }));
        id
    }

    /// Access helper: `array[exprs...]`.
    pub fn at(&self, array: ArrayId, indices: &[AffineExpr]) -> Access {
        assert_eq!(
            indices.len(),
            self.arrays[array.0 as usize].dims.len(),
            "access arity mismatch for {}",
            self.arrays[array.0 as usize].name
        );
        Access::new(array, indices.to_vec())
    }

    /// Expression helpers.
    pub fn v(&self, l: LoopId) -> AffineExpr {
        AffineExpr::var(l)
    }
    /// Constant affine expression.
    pub fn c(&self, x: i64) -> AffineExpr {
        AffineExpr::constant(x)
    }
    /// `l + c`
    pub fn vp(&self, l: LoopId, c: i64) -> AffineExpr {
        AffineExpr::var(l).plus_const(c)
    }
    /// `a + b` over iterators
    pub fn sum(&self, a: &AffineExpr, b: &AffineExpr) -> AffineExpr {
        a.add(b)
    }

    /// Finalize into a [`Kernel`] (computes all loop/statement metadata).
    pub fn finish(self) -> Kernel {
        assert!(self.open.is_empty(), "unclosed loops at finish()");
        let mut frames = self.frames;
        let roots = frames.pop().unwrap();
        assert!(frames.is_empty());
        Kernel::finalize(&self.name, self.dtype, self.arrays, roots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangular_bounds_allowed() {
        let mut kb = KernelBuilder::new("tri", DType::F32);
        let a = kb.array("a", &[10, 10], ArrayDir::InOut);
        kb.for_const("i", 0, 10, |kb, i| {
            // for j in [0, i)
            kb.for_expr("j", kb.c(0), kb.v(i), |kb, j| {
                kb.stmt(
                    "S0",
                    vec![kb.at(a, &[kb.v(i), kb.v(j)])],
                    vec![kb.at(a, &[kb.v(j), kb.v(i)])],
                    &[(OpKind::Add, 1)],
                );
            });
        });
        let k = kb.finish();
        assert_eq!(k.n_loops(), 2);
        let (lb, ub) = k.loop_bounds(LoopId(1));
        assert!(lb.is_constant());
        assert!(!ub.is_constant());
    }

    #[test]
    #[should_panic(expected = "references non-enclosing loop")]
    fn rejects_escaping_bound() {
        let mut kb = KernelBuilder::new("bad", DType::F32);
        let a = kb.array("a", &[4], ArrayDir::Out);
        let mut leaked = None;
        kb.for_const("i", 0, 4, |kb, i| {
            leaked = Some(i);
            kb.stmt("S0", vec![kb.at(a, &[kb.v(i)])], vec![], &[(OpKind::Add, 1)]);
        });
        // sibling loop referencing i's iterator is invalid
        kb.for_expr("j", AffineExpr::constant(0), AffineExpr::var(leaked.unwrap()), |_, _| {});
    }

    #[test]
    #[should_panic(expected = "statement outside any loop")]
    fn rejects_toplevel_stmt() {
        let mut kb = KernelBuilder::new("bad", DType::F32);
        let a = kb.array("a", &[4], ArrayDir::Out);
        let acc = kb.at(a, &[kb.c(0)]);
        kb.stmt("S0", vec![acc], vec![], &[(OpKind::Add, 1)]);
    }

    #[test]
    #[should_panic(expected = "access arity mismatch")]
    fn rejects_bad_arity() {
        let mut kb = KernelBuilder::new("bad", DType::F32);
        let a = kb.array("a", &[4, 4], ArrayDir::Out);
        let _ = kb.at(a, &[kb.c(0)]);
    }
}
