//! Affine expressions over loop iterators: `Σ coeff_i · iter_i + const`.
//!
//! Used for loop bounds (triangular loops in `lu`, `trisolv`,
//! `gramschmidt`, `symm`, …) and for array index functions. Exactness of
//! everything downstream (trip counts, dependence distances, footprints)
//! rests on this closed form.

use super::LoopId;

/// `Σ coeff_i · iter_i + constant` over loop iterators.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct AffineExpr {
    /// `(loop, coefficient)` terms; kept sorted by loop id, no zero coeffs.
    pub terms: Vec<(LoopId, i64)>,
    /// The constant term.
    pub constant: i64,
}

impl AffineExpr {
    /// The constant expression `c`.
    pub fn constant(c: i64) -> AffineExpr {
        AffineExpr {
            terms: vec![],
            constant: c,
        }
    }

    /// The iterator of `loop_id` itself (coefficient 1).
    pub fn var(loop_id: LoopId) -> AffineExpr {
        AffineExpr {
            terms: vec![(loop_id, 1)],
            constant: 0,
        }
    }

    /// `coeff * iter` (normalized; zero coeff collapses to a constant).
    pub fn var_scaled(loop_id: LoopId, coeff: i64) -> AffineExpr {
        let mut e = AffineExpr {
            terms: vec![(loop_id, coeff)],
            constant: 0,
        };
        e.normalize();
        e
    }

    /// Add `c` to the constant term.
    pub fn plus_const(mut self, c: i64) -> AffineExpr {
        self.constant += c;
        self
    }

    /// Sum of two affine expressions.
    pub fn add(&self, other: &AffineExpr) -> AffineExpr {
        let mut out = self.clone();
        for &(l, c) in &other.terms {
            out.add_term(l, c);
        }
        out.constant += other.constant;
        out.normalize();
        out
    }

    /// Add `c * iter_l` in place (normalizing zeros and order).
    pub fn add_term(&mut self, l: LoopId, c: i64) {
        if let Some(t) = self.terms.iter_mut().find(|t| t.0 == l) {
            t.1 += c;
        } else {
            self.terms.push((l, c));
        }
        self.normalize();
    }

    fn normalize(&mut self) {
        self.terms.retain(|t| t.1 != 0);
        self.terms.sort_by_key(|t| t.0);
    }

    /// True when no iterator terms remain.
    pub fn is_constant(&self) -> bool {
        self.terms.is_empty()
    }

    /// Coefficient of `l` (0 if absent).
    pub fn coeff(&self, l: LoopId) -> i64 {
        self.terms
            .iter()
            .find(|t| t.0 == l)
            .map(|t| t.1)
            .unwrap_or(0)
    }

    /// Evaluate with a concrete iterator assignment; unassigned iterators
    /// panic (callers must pass complete environments).
    pub fn eval(&self, env: &dyn Fn(LoopId) -> i64) -> i64 {
        self.constant
            + self
                .terms
                .iter()
                .map(|&(l, c)| c * env(l))
                .sum::<i64>()
    }

    /// Interval of values over iterator boxes `ranges(l) = [lo, hi]`
    /// (inclusive). Exact for affine forms: extremes occur at box corners,
    /// and for affine functions each term's extreme is independent.
    pub fn bounds(&self, ranges: &dyn Fn(LoopId) -> (i64, i64)) -> (i64, i64) {
        let mut lo = self.constant;
        let mut hi = self.constant;
        for &(l, c) in &self.terms {
            let (rlo, rhi) = ranges(l);
            if c >= 0 {
                lo += c * rlo;
                hi += c * rhi;
            } else {
                lo += c * rhi;
                hi += c * rlo;
            }
        }
        (lo, hi)
    }

    /// Loops referenced by this expression.
    pub fn loops(&self) -> impl Iterator<Item = LoopId> + '_ {
        self.terms.iter().map(|t| t.0)
    }

    /// Difference `self - other`.
    pub fn sub(&self, other: &AffineExpr) -> AffineExpr {
        let mut neg = other.clone();
        for t in &mut neg.terms {
            t.1 = -t.1;
        }
        neg.constant = -neg.constant;
        self.add(&neg)
    }
}

impl std::fmt::Display for AffineExpr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for &(l, c) in &self.terms {
            if first {
                if c == 1 {
                    write!(f, "{l}")?;
                } else if c == -1 {
                    write!(f, "-{l}")?;
                } else {
                    write!(f, "{c}*{l}")?;
                }
                first = false;
            } else if c >= 0 {
                if c == 1 {
                    write!(f, " + {l}")?;
                } else {
                    write!(f, " + {c}*{l}")?;
                }
            } else if c == -1 {
                write!(f, " - {l}")?;
            } else {
                write!(f, " - {}*{l}", -c)?;
            }
        }
        if first {
            write!(f, "{}", self.constant)?;
        } else if self.constant > 0 {
            write!(f, " + {}", self.constant)?;
        } else if self.constant < 0 {
            write!(f, " - {}", -self.constant)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const L0: LoopId = LoopId(0);
    const L1: LoopId = LoopId(1);

    #[test]
    fn construction_and_eval() {
        // 2*i - j + 3
        let e = AffineExpr::var_scaled(L0, 2)
            .add(&AffineExpr::var_scaled(L1, -1))
            .plus_const(3);
        let v = e.eval(&|l| if l == L0 { 5 } else { 2 });
        assert_eq!(v, 2 * 5 - 2 + 3);
    }

    #[test]
    fn normalization_removes_zeros() {
        let e = AffineExpr::var(L0).add(&AffineExpr::var_scaled(L0, -1));
        assert!(e.is_constant());
        assert_eq!(e.constant, 0);
    }

    #[test]
    fn interval_bounds_exact() {
        // i - j over i in [0,9], j in [0,4] -> [-4, 9]
        let e = AffineExpr::var(L0).sub(&AffineExpr::var(L1));
        let (lo, hi) = e.bounds(&|l| if l == L0 { (0, 9) } else { (0, 4) });
        assert_eq!((lo, hi), (-4, 9));
    }

    #[test]
    fn display_readable() {
        let e = AffineExpr::var(L0)
            .add(&AffineExpr::var_scaled(L1, -2))
            .plus_const(1);
        assert_eq!(format!("{e}"), "L0 - 2*L1 + 1");
        assert_eq!(format!("{}", AffineExpr::constant(7)), "7");
    }
}
