//! Design-space structure: pipeline configurations and space-size counting.
//!
//! The space follows Merlin's validity rules (Section 5.2):
//! * per statement, at most one pipelined loop among its nest (Eq 5) — i.e.
//!   the pipelined loops form an **antichain** in the loop forest;
//! * loops strictly under a pipelined loop are fully unrolled (Eq 15), so
//!   they contribute no free UF choice;
//! * `UF` and `tile` must divide the trip count (Eqs 6–7), which requires a
//!   constant trip count;
//! * loops with non-constant TC (triangular) cannot be unrolled (Vitis
//!   restriction, Section 3.1) — their UF is fixed at 1.

use super::{Design, LoopPragma};
use crate::ir::{Kernel, LoopId};
use crate::poly::Analysis;
use crate::util::divisors;

/// One pipeline configuration: an antichain of pipelined loops. Innermost
/// loops not dominated by a chosen loop are auto-pipelined by Vitis/Merlin
/// (Section 3.1), which the model applies implicitly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    /// The chosen pipelined loops (no ancestor relations among them).
    pub pipelined: Vec<LoopId>,
}

/// The enumerable pragma design space of one kernel.
pub struct Space<'k> {
    /// The kernel the space belongs to.
    pub kernel: &'k Kernel,
    /// Divisor sets per loop (UF candidates); singleton `[1]` for loops
    /// with non-constant TC.
    pub uf_candidates: Vec<Vec<u64>>,
    /// All valid pipeline configurations.
    pub pipeline_configs: Vec<PipelineConfig>,
}

impl<'k> Space<'k> {
    /// Enumerate menus and pipeline configurations for `kernel`.
    pub fn new(kernel: &'k Kernel, analysis: &Analysis) -> Space<'k> {
        let uf_candidates = (0..kernel.n_loops())
            .map(|i| {
                let tc = &analysis.tcs[i];
                if tc.is_constant() && tc.max > 0 {
                    divisors(tc.max)
                } else {
                    vec![1]
                }
            })
            .collect();
        let pipeline_configs = enumerate_pipeline_configs(kernel);
        Space {
            kernel,
            uf_candidates,
            pipeline_configs,
        }
    }

    /// UF candidates for loop `l`, additionally capped by the dependence
    /// distance (Eq 8) and a partitioning bound.
    pub fn ufs(&self, l: LoopId, analysis: &Analysis, cap: u64) -> Vec<u64> {
        let dep = &analysis.deps.per_loop[l.0 as usize];
        let dist_cap = match dep.min_distance {
            // distance-1 reductions may still unroll (tree reduction);
            // distance d > 1 recurrences cap UF at d (Eq 8)
            Some(d) if d > 1 => d,
            Some(_) if dep.serializing && !dep.reduction => 1,
            _ => u64::MAX,
        };
        self.uf_candidates[l.0 as usize]
            .iter()
            .copied()
            .filter(|&u| u <= cap.min(dist_cap))
            .collect()
    }

    /// Number of valid designs (Table 2 / Table 5 "Space S" column):
    /// Σ over pipeline configs of Π over free loops of |UF choices| ×
    /// |tile choices| (tile on nest roots, the caching knob).
    pub fn size(&self) -> f64 {
        let k = self.kernel;
        let mut total = 0f64;
        for cfg in &self.pipeline_configs {
            let mut prod = 1f64;
            for i in 0..k.n_loops() {
                let l = LoopId(i as u32);
                // loops strictly under a pipelined loop: UF forced (Eq 15)
                let under = cfg
                    .pipelined
                    .iter()
                    .any(|&p| k.is_under(l, p));
                if under {
                    continue;
                }
                prod *= self.uf_candidates[i].len() as f64;
                if k.loop_meta(l).parent.is_none() {
                    // tile choices on the nest root
                    prod *= self.uf_candidates[i].len() as f64;
                }
            }
            total += prod;
        }
        total
    }
}

/// Enumerate antichains of the loop forest (each statement sees ≤ 1
/// pipelined loop). Per nest tree the choices are: pipeline some loop `l`
/// (covering `l`'s subtree) or recurse into children independently; plus
/// the "no explicit pipeline" option (auto-pipelining handles innermost).
fn enumerate_pipeline_configs(k: &Kernel) -> Vec<PipelineConfig> {
    // per nest root, the list of antichain options (each a Vec<LoopId>,
    // possibly empty = rely on auto-pipeline)
    fn options(k: &Kernel, l: LoopId) -> Vec<Vec<LoopId>> {
        let meta = k.loop_meta(l);
        let mut opts: Vec<Vec<LoopId>> = vec![vec![l]]; // pipeline here
        if meta.children.is_empty() {
            opts.push(vec![]); // innermost: auto-pipeline
            return opts;
        }
        // don't pipeline here: cross-product of child options
        let mut combos: Vec<Vec<LoopId>> = vec![vec![]];
        for &c in &meta.children {
            let child_opts = options(k, c);
            let mut next = Vec::new();
            for base in &combos {
                for co in &child_opts {
                    let mut v = base.clone();
                    v.extend(co.iter().copied());
                    next.push(v);
                }
            }
            combos = next;
        }
        opts.extend(combos);
        opts
    }

    let mut configs: Vec<Vec<LoopId>> = vec![vec![]];
    for root in k.nest_roots() {
        let root_opts = options(k, root);
        let mut next = Vec::new();
        for base in &configs {
            for ro in &root_opts {
                let mut v = base.clone();
                v.extend(ro.iter().copied());
                next.push(v);
            }
        }
        configs = next;
    }
    // dedup (sibling recursion can produce duplicates of the empty set)
    let mut seen = std::collections::BTreeSet::new();
    configs
        .into_iter()
        .filter(|c| {
            let mut key = c.clone();
            key.sort();
            seen.insert(key)
        })
        .map(|pipelined| PipelineConfig { pipelined })
        .collect()
}

/// Materialize a [`Design`] from per-loop UF choices + a pipeline config,
/// applying the Eq 15 full-unroll rule for loops under the pipeline.
pub fn materialize(
    k: &Kernel,
    analysis: &Analysis,
    cfg: &PipelineConfig,
    ufs: &dyn Fn(LoopId) -> u64,
    tiles: &dyn Fn(LoopId) -> u64,
) -> Design {
    let mut d = Design::empty(k);
    materialize_into(k, analysis, cfg, ufs, tiles, &mut d);
    d
}

/// [`materialize`] into a caller-owned design buffer — the parallel
/// solver's leaf path reuses one buffer per worker so interior
/// branch-and-bound nodes stay allocation-free.
pub fn materialize_into(
    k: &Kernel,
    analysis: &Analysis,
    cfg: &PipelineConfig,
    ufs: &dyn Fn(LoopId) -> u64,
    tiles: &dyn Fn(LoopId) -> u64,
    d: &mut Design,
) {
    debug_assert_eq!(d.pragmas.len(), k.n_loops(), "buffer/kernel mismatch");
    for i in 0..k.n_loops() {
        let l = LoopId(i as u32);
        let under_pipe = cfg.pipelined.iter().any(|&p| k.is_under(l, p));
        let tc = &analysis.tcs[i];
        let info = &analysis.deps.per_loop[i];
        let uf = if under_pipe {
            if info.reduction || info.serializing {
                // reduction loops keep their chosen tree-unroll factor
                // (Section 5.4's TC/uf·log2(uf) term); order-enforcing
                // loops stay serial
                ufs(l).max(1)
            } else if tc.is_constant() {
                // parallel loops are fully unrolled under a pipeline (Eq 15)
                tc.max.max(1)
            } else {
                1
            }
        } else {
            ufs(l)
        };
        d.pragmas[i] = LoopPragma {
            uf,
            tile: tiles(l),
            pipeline: cfg.pipelined.contains(&l),
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;
    use crate::poly::Analysis;

    #[test]
    fn gemm_pipeline_configs() {
        let k = crate::benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        // nest i(j0, k(j1)): {i} ∪ ({j0},{}) × ({k},{j1},{}) → 1 + 2×3 = 7
        assert_eq!(s.pipeline_configs.len(), 7);
    }

    #[test]
    fn atax_sibling_loops_independent() {
        let k = crate::benchmarks::kernel_atax(116, 124, DType::F32);
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        // nest A: single loop i0 → {i0}, {} = 2
        // nest B: i1(j1, j2) → {i1}, then j1⊗j2 ∈ {j1,∅}×{j2,∅} = 4 → 5
        // total = 2 × 5 = 10
        assert_eq!(s.pipeline_configs.len(), 10);
    }

    #[test]
    fn space_size_astronomical_for_2mm() {
        let k = crate::benchmarks::kernel_2mm(180, 190, 210, 220, DType::F32);
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let size = s.size();
        // paper reports 1.37e10 valid designs; our validity convention
        // lands in the same magnitude band
        assert!(size > 1e8, "space {size}");
        assert!(size < 1e13, "space {size}");
    }

    #[test]
    fn triangular_loops_have_no_unroll() {
        let k = crate::benchmarks::kernel_lu(120, DType::F32);
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        // loops j0,k0 (triangular) must have singleton UF candidates
        assert_eq!(s.uf_candidates[1], vec![1]);
        assert_eq!(s.uf_candidates[2], vec![1]);
        // i (constant) has all divisors of 120
        assert_eq!(s.uf_candidates[0].len(), crate::util::divisors(120).len());
    }

    #[test]
    fn eq8_distance_caps_uf() {
        use crate::ir::{ArrayDir, KernelBuilder, OpKind};
        let mut kb = KernelBuilder::new("rec2", DType::F32);
        let y = kb.array("y", &[96], ArrayDir::InOut);
        kb.for_const("j", 0, 96, |kb, j| {
            kb.stmt(
                "S0",
                vec![kb.at(y, &[kb.v(j)])],
                vec![kb.at(y, &[kb.vp(j, -2)])],
                &[(OpKind::Add, 1)],
            );
        });
        let k = kb.finish();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let ufs = s.ufs(LoopId(0), &a, u64::MAX);
        assert_eq!(ufs, vec![1, 2], "UF capped at dependence distance 2");
    }

    #[test]
    fn materialize_into_reuses_buffer_identically() {
        let k = crate::benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let mut buf = Design::empty(&k);
        for cfg in &s.pipeline_configs {
            let fresh = materialize(&k, &a, cfg, &|l| if l.0 == 0 { 2 } else { 1 }, &|_| 1);
            materialize_into(&k, &a, cfg, &|l| if l.0 == 0 { 2 } else { 1 }, &|_| 1, &mut buf);
            assert_eq!(fresh, buf, "{:?}", cfg.pipelined);
        }
    }

    #[test]
    fn materialize_full_unrolls_under_pipe() {
        let k = crate::benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let cfg = s
            .pipeline_configs
            .iter()
            .find(|c| c.pipelined == vec![LoopId(2)])
            .unwrap();
        let d = materialize(&k, &a, cfg, &|_| 1, &|_| 1);
        assert!(d.get(LoopId(2)).pipeline);
        assert_eq!(d.get(LoopId(3)).uf, 70, "j1 fully unrolled under pipe");
    }
}
