//! Merlin pragma configurations — the unknowns of the NLP.
//!
//! A [`Design`] assigns each loop its property vector entries the user
//! controls (Section 3.1's PV): `parallel factor=UF`, `tile factor=T`,
//! `pipeline` on/off. Cache pragmas are applied automatically by (our
//! simulated) Merlin at the outermost legal position, with `tile` shrinking
//! the cached working set (Section 2.1).

pub mod space;

pub use space::{PipelineConfig, Space};

use crate::ir::{Kernel, LoopId};

/// Per-loop pragma settings (`uf = 1`, `tile = 1`, `pipeline = false` means
/// "no pragma"). The derived `(uf, tile, pipeline)` lexicographic order
/// gives [`Design`] a total order — the deterministic final tie-break of
/// the parallel solver's top-k reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LoopPragma {
    /// `#pragma ACCEL parallel factor=uf`
    pub uf: u64,
    /// `#pragma ACCEL tile factor=tile`
    pub tile: u64,
    /// `#pragma ACCEL pipeline`
    pub pipeline: bool,
}

impl Default for LoopPragma {
    fn default() -> Self {
        LoopPragma {
            uf: 1,
            tile: 1,
            pipeline: false,
        }
    }
}

/// A complete pragma configuration for one kernel. Totally ordered (the
/// per-loop pragma vector, lexicographically): two distinct designs never
/// compare equal, which the parallel solver's deterministic merge relies
/// on.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Design {
    /// One pragma triple per loop, by loop id.
    pub pragmas: Vec<LoopPragma>,
}

impl Design {
    /// The pragma-free configuration (what "Original" rows measure).
    pub fn empty(k: &Kernel) -> Design {
        Design {
            pragmas: vec![LoopPragma::default(); k.n_loops()],
        }
    }

    /// Pragma triple of loop `l`.
    pub fn get(&self, l: LoopId) -> LoopPragma {
        self.pragmas[l.0 as usize]
    }
    /// Mutable pragma triple of loop `l`.
    pub fn get_mut(&mut self, l: LoopId) -> &mut LoopPragma {
        &mut self.pragmas[l.0 as usize]
    }

    /// Builder-style copy with loop `l` replaced.
    pub fn with(mut self, l: LoopId, p: LoopPragma) -> Design {
        self.pragmas[l.0 as usize] = p;
        self
    }

    /// Pipelined loops, if any.
    pub fn pipelined(&self) -> impl Iterator<Item = LoopId> + '_ {
        self.pragmas
            .iter()
            .enumerate()
            .filter(|(_, p)| p.pipeline)
            .map(|(i, _)| LoopId(i as u32))
    }

    /// The pipelined loop governing statement-bearing loop `l`: the nearest
    /// enclosing (or self) pipelined loop.
    pub fn pipeline_above(&self, k: &Kernel, l: LoopId) -> Option<LoopId> {
        let mut cur = Some(l);
        while let Some(c) = cur {
            if self.get(c).pipeline {
                return Some(c);
            }
            cur = k.loop_meta(c).parent;
        }
        None
    }

    /// Per-dimension partitioning factors required for array `a`: for
    /// each dimension, the max UF over the loops indexing it. The
    /// `codegen` Vitis dialect emits these as one `array_partition`
    /// pragma per dimension.
    pub fn partitioning_dims(&self, k: &Kernel, a: crate::ir::ArrayId) -> Vec<u64> {
        let mut per_dim: Vec<u64> = vec![1; k.array(a).dims.len()];
        for s in k.stmts() {
            for (acc, _) in k.stmt_accesses(s.id) {
                if acc.array != a {
                    continue;
                }
                for (d, idx) in acc.indices.iter().enumerate() {
                    for l in idx.loops() {
                        per_dim[d] = per_dim[d].max(self.get(l).uf);
                    }
                }
            }
        }
        per_dim
    }

    /// Array-partitioning factor required for array `a`: the product over
    /// dimensions of the max UF of loops indexing each dimension (Section 6:
    /// "the product of loops that iterate the same arrays on different
    /// dimensions").
    pub fn partitioning(&self, k: &Kernel, a: crate::ir::ArrayId) -> u64 {
        self.partitioning_dims(k, a).iter().product()
    }

    /// Max partitioning over all arrays (the DSE ladder constraint).
    pub fn max_partitioning(&self, k: &Kernel) -> u64 {
        k.arrays
            .iter()
            .map(|a| self.partitioning(k, a.id))
            .max()
            .unwrap_or(1)
    }

    /// Stable fingerprint for dedup / deterministic oracles.
    pub fn fingerprint(&self) -> String {
        let mut s = String::new();
        for (i, p) in self.pragmas.iter().enumerate() {
            if p.uf != 1 || p.tile != 1 || p.pipeline {
                s.push_str(&format!(
                    "L{i}:u{}t{}p{};",
                    p.uf,
                    p.tile,
                    if p.pipeline { 1 } else { 0 }
                ));
            }
        }
        if s.is_empty() {
            s.push_str("empty");
        }
        s
    }

    /// Render the design as paper-style pragma annotations (Listing 11).
    pub fn render(&self, k: &Kernel) -> String {
        let mut out = String::new();
        for (i, p) in self.pragmas.iter().enumerate() {
            let l = LoopId(i as u32);
            let indent = "  ".repeat(k.loop_meta(l).depth as usize);
            if p.pipeline {
                out.push_str(&format!("{indent}#pragma ACCEL pipeline\n"));
            }
            if p.tile > 1 {
                out.push_str(&format!("{indent}#pragma ACCEL tile factor={}\n", p.tile));
            }
            if p.uf > 1 {
                out.push_str(&format!(
                    "{indent}#pragma ACCEL parallel factor={}\n",
                    p.uf
                ));
            }
            out.push_str(&format!(
                "{indent}for ({}) [TC via bounds {} .. {}]\n",
                k.loop_name(l),
                k.loop_bounds(l).0,
                k.loop_bounds(l).1
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;

    #[test]
    fn empty_design_is_pragma_free() {
        let k = crate::benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let d = Design::empty(&k);
        assert_eq!(d.pragmas.len(), 4);
        assert!(d.pipelined().next().is_none());
        assert_eq!(d.fingerprint(), "empty");
    }

    #[test]
    fn partitioning_is_cross_dim_product() {
        let k = crate::benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        // loops: i(0), j0(1), k(2), j1(3); C[i][j], A[i][k], B[k][j1]
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(2)).uf = 8; // k
        d.get_mut(LoopId(3)).uf = 5; // j1
        let a_id = k.array_by_name("A").unwrap().id;
        let b_id = k.array_by_name("B").unwrap().id;
        let c_id = k.array_by_name("C").unwrap().id;
        assert_eq!(d.partitioning(&k, a_id), 8); // A[i][k] → dim1 by k
        assert_eq!(d.partitioning(&k, b_id), 40); // B[k][j1] → 8*5
        assert_eq!(d.partitioning(&k, c_id), 5); // C[i][j1] → dim1 by j1
        assert_eq!(d.max_partitioning(&k), 40);
    }

    #[test]
    fn pipeline_above_walks_ancestry() {
        let k = crate::benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(2)).pipeline = true; // k
        assert_eq!(d.pipeline_above(&k, LoopId(3)), Some(LoopId(2)));
        assert_eq!(d.pipeline_above(&k, LoopId(2)), Some(LoopId(2)));
        assert_eq!(d.pipeline_above(&k, LoopId(0)), None);
    }

    #[test]
    fn fingerprint_distinguishes() {
        let k = crate::benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let d1 = Design::empty(&k).with(
            LoopId(1),
            LoopPragma {
                uf: 2,
                tile: 1,
                pipeline: true,
            },
        );
        let d2 = Design::empty(&k).with(
            LoopId(1),
            LoopPragma {
                uf: 4,
                tile: 1,
                pipeline: true,
            },
        );
        assert_ne!(d1.fingerprint(), d2.fingerprint());
    }
}
