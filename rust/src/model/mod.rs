//! The analytical latency + resource **lower bound** model (Section 4,
//! Appendix B), parameterized by the pragma configuration.
//!
//! * [`eval`] — the composition template of Section 4.1 (`I`/`C`/`SL`
//!   operators) instantiated over the kernel's summary AST: pipelining
//!   (Theorems 4.8/4.9), partial/full unrolling (4.5–4.7), coarse-grained
//!   replication (4.11), sequential loops (4.10), tree reductions under
//!   unsafe-math (4.7), DSP accounting (4.12), memory transfers
//!   (4.13/4.14), and the final composition (4.15/4.16).
//! * [`features`] — the dense batched encoding of the same computation for
//!   the AOT-compiled XLA evaluator (see `python/compile/kernels/`), plus
//!   the pure-Rust reference evaluation of that encoding.
//! * [`sym`] — **the model front door**: the symbolic bound-model IR. One
//!   [`sym::BoundModel`] per kernel carries the latency objective and the
//!   Eqs 1–15 constraints as first-class values, and serves all three
//!   consumers — the compiled allocation-free batch evaluator
//!   ([`sym::CompiledModel`]), the NLP lowering (`nlp::NlpProblem` is a
//!   thin view over it), and partial-configuration interval bounds
//!   ([`sym::BoundModel::lower_bound`]). [`eval`] remains the executable
//!   reference the IR is property-tested against.
//!
//! The invariant maintained throughout (and property-tested in
//! `rust/tests/property_invariants.rs`): **for any legal configuration the
//! model's latency never exceeds the HLS oracle's measured latency when the
//! pragmas are applied as requested** — the paper's Theorem B.21 property
//! that makes DSE pruning safe.

pub mod eval;
pub mod features;
pub mod sym;

pub use eval::{evaluate, nest_latencies, top_scope_sum_combine, ModelResult, NestBreakdown};
pub use features::{encode_design, eval_features, Abi, DesignFeatures};
pub use sym::{BoundModel, CompiledModel, CompiledResult, PartialDesign};
