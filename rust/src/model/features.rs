//! Dense batched encoding of the lower-bound model — the ABI shared by the
//! Rust reference evaluator, the pure-jnp oracle (`kernels/ref.py`), the
//! Pallas kernel (`kernels/lat_bound.py`), and the AOT artifact executed by
//! `runtime`.
//!
//! A design is flattened into up to [`Abi::UNITS`] *units*. Each unit is
//! either a statement's contribution, a pipeline's `II×(TC/UF−1)` ramp, or
//! a memory-transfer term; every unit carries up to [`Abi::LOOPS`] loop rows
//! describing the factors that scale it:
//!
//! ```text
//! above   = Π rows [above_par: tc/uf] × Π rows [above_seq: tc]
//! tree    = Π rows [under_red: (tc/uf) × max(1, ceil(log2 uf))]
//! lat_u   = above × (il_base + il_red × tree + ii × (pipe_tc/pipe_uf − 1))
//! mcu     = Π rows uf
//! dsp_u   = dsp_base × mcu / max(ii_share, 1)
//!
//! latency = Σ_{w_sum=1} lat_u  +  max_{w_sum=0} lat_u
//! dsp     = max_u dsp_u
//! ```
//!
//! The encoding **under-approximates** the precise recursive model in two
//! documented places (independent-component maxing, DSP maxing across
//! units) — both keep the result a valid *lower bound*, which is the only
//! property bulk pruning needs. `eval_features` must agree with the XLA
//! artifact to 1e-6 relative (tested in `integration_runtime.rs`), and stay
//! ≤ the precise `eval::evaluate` (property-tested).

use crate::hls::Device;
use crate::ir::{Kernel, Node, StmtId};
use crate::poly::Analysis;
use crate::pragma::Design;

/// ABI constants — must match `python/compile/kernels/lat_bound.py`.
pub struct Abi;

impl Abi {
    /// Max pipeline units the encoding carries.
    pub const UNITS: usize = 16;
    /// Max loops per unit the encoding carries.
    pub const LOOPS: usize = 8;
    /// per-loop features: tc, uf, above_par, above_seq, under_red, valid
    pub const F: usize = 6;
    /// per-unit scalars: il_base, il_red, ii, pipe_tc, pipe_uf, dsp_base,
    /// w_sum, valid
    pub const G: usize = 8;
    /// Flattened lengths per design.
    pub const LOOPS_LEN: usize = Self::UNITS * Self::LOOPS * Self::F;
    /// Flattened length of the per-unit block.
    pub const UNITS_LEN: usize = Self::UNITS * Self::G;
}

/// One encoded design (flattened row-major: `[UNITS][LOOPS][F]` and
/// `[UNITS][G]`).
#[derive(Clone, Debug)]
pub struct DesignFeatures {
    /// `[UNITS][LOOPS][F]` row-major per-loop features.
    pub loops: Vec<f64>,
    /// `[UNITS][G]` row-major per-unit scalars.
    pub units: Vec<f64>,
}

impl DesignFeatures {
    /// All-zero (padding) feature block.
    pub fn zeros() -> DesignFeatures {
        DesignFeatures {
            loops: vec![0.0; Abi::LOOPS_LEN],
            units: vec![0.0; Abi::UNITS_LEN],
        }
    }

    #[inline]
    fn loop_row(&mut self, u: usize, l: usize) -> &mut [f64] {
        let base = (u * Abi::LOOPS + l) * Abi::F;
        &mut self.loops[base..base + Abi::F]
    }
    #[inline]
    fn unit_row(&mut self, u: usize) -> &mut [f64] {
        let base = u * Abi::G;
        &mut self.units[base..base + Abi::G]
    }
}

struct Encoder<'a> {
    k: &'a Kernel,
    a: &'a Analysis,
    dev: &'a Device,
    d: &'a Design,
    out: DesignFeatures,
    next_unit: usize,
    overflow: bool,
}

/// Loop-row description accumulated while walking down the tree.
#[derive(Clone, Copy)]
struct RowDesc {
    tc: f64,
    uf: f64,
    above_par: bool,
    above_seq: bool,
    under_red: bool,
}

impl<'a> Encoder<'a> {
    fn emit_unit(
        &mut self,
        rows: &[RowDesc],
        il_base: f64,
        il_red: f64,
        ii: f64,
        pipe_tc: f64,
        pipe_uf: f64,
        dsp_base: f64,
        w_sum: bool,
    ) {
        if self.next_unit >= Abi::UNITS {
            self.overflow = true;
            return;
        }
        let u = self.next_unit;
        self.next_unit += 1;
        for (li, r) in rows.iter().take(Abi::LOOPS).enumerate() {
            let row = self.out.loop_row(u, li);
            row[0] = r.tc;
            row[1] = r.uf.max(1.0);
            row[2] = r.above_par as u8 as f64;
            row[3] = r.above_seq as u8 as f64;
            row[4] = r.under_red as u8 as f64;
            row[5] = 1.0;
        }
        if rows.len() > Abi::LOOPS {
            self.overflow = true;
        }
        let unit = self.out.unit_row(u);
        unit[0] = il_base;
        unit[1] = il_red;
        unit[2] = ii;
        unit[3] = pipe_tc.max(1.0);
        unit[4] = pipe_uf.max(1.0);
        unit[5] = dsp_base;
        unit[6] = w_sum as u8 as f64;
        unit[7] = 1.0;
    }
}

/// Encode one design. Returns `None` on overflow (more units/loops than the
/// ABI can carry — callers fall back to the precise Rust evaluator).
pub fn encode_design(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    d: &Design,
) -> Option<DesignFeatures> {
    let mut enc = Encoder {
        k,
        a,
        dev,
        d,
        out: DesignFeatures::zeros(),
        next_unit: 0,
        overflow: false,
    };

    // memory-transfer unit (Theorem 4.14 lower bound: max over parallel
    // input transfers + max over output transfers)
    let mut in_max = 0f64;
    let mut out_max = 0f64;
    for arr in &k.arrays {
        let cyc = dev.transfer_cycles(arr.footprint_bytes(k.dtype));
        if arr.dir.is_live_in() {
            in_max = in_max.max(cyc);
        }
        if arr.dir.is_live_out() {
            out_max = out_max.max(cyc);
        }
    }
    enc.emit_unit(&[], in_max + out_max, 0.0, 0.0, 1.0, 1.0, 0.0, true);

    // walk the tree
    let roots: Vec<&Node> = k.roots.iter().collect();
    walk_scope(&mut enc, &roots, &mut Vec::new(), true);

    if enc.overflow {
        None
    } else {
        Some(enc.out)
    }
}

/// Walk a sibling scope above any pipeline. `above` is the stack of loop
/// rows accumulated so far. Once a scope splits into > 1 independent
/// component, everything underneath is routed to the max set (`w_sum = 0`):
/// `max` over individual units under-approximates `max` over component
/// sums, which keeps the result a valid lower bound.
fn walk_scope(enc: &mut Encoder, nodes: &[&Node], above: &mut Vec<RowDesc>, parent_sum: bool) {
    // component analysis over siblings
    let stmt_sets: Vec<Vec<StmtId>> = nodes.iter().map(|n| collect_stmts(n)).collect();
    let n = nodes.len();
    let mut comp: Vec<usize> = (0..n).collect();
    fn find(c: &mut Vec<usize>, i: usize) -> usize {
        if c[i] != i {
            let r = find(c, c[i]);
            c[i] = r;
        }
        c[i]
    }
    for i in 0..n {
        for j in i + 1..n {
            let dep = stmt_sets[i].iter().any(|&s1| {
                stmt_sets[j]
                    .iter()
                    .any(|&s2| enc.a.deps.stmts_dependent(s1, s2))
            });
            if dep {
                let (ri, rj) = (find(&mut comp, i), find(&mut comp, j));
                if ri != rj {
                    comp[ri] = rj;
                }
            }
        }
    }
    let n_comps = {
        let mut roots: Vec<usize> = (0..n).map(|i| find(&mut comp, i)).collect();
        roots.sort_unstable();
        roots.dedup();
        roots.len()
    };
    let w_sum = parent_sum && n_comps <= 1;

    for node in nodes {
        match node {
            Node::Stmt(s) => {
                // statement directly in an above-pipe scope: its own chain,
                // replicated over the above iteration factors
                let il = stmt_chain(enc, s.id);
                let dsp = stmt_dsp(enc, s.id);
                enc.emit_unit(above, il, 0.0, 0.0, 1.0, 1.0, dsp, w_sum);
            }
            Node::Loop(l) => {
                let p = enc.d.get(l.id);
                let info = enc.a.deps.loop_info(l.id).clone();
                let tc = enc.a.tc(l.id).avg.max(1.0);
                let innermost = enc.k.loop_meta(l.id).innermost;
                if p.pipeline || innermost {
                    emit_pipeline(enc, l.id, &l.body, above, w_sum);
                } else {
                    let row = if info.reduction || info.serializing {
                        RowDesc {
                            tc,
                            uf: 1.0,
                            above_par: false,
                            above_seq: true,
                            under_red: false,
                        }
                    } else {
                        RowDesc {
                            tc,
                            uf: (p.uf.max(1) as f64).min(tc),
                            above_par: true,
                            above_seq: false,
                            under_red: false,
                        }
                    };
                    above.push(row);
                    let body: Vec<&Node> = l.body.iter().collect();
                    walk_scope(enc, &body, above, w_sum);
                    above.pop();
                }
            }
        }
    }
}

/// Emit the units of one pipelined region: one unit per statement (IL
/// contributions with tree factors) plus one ramp unit for `II×(TC/UF−1)`.
fn emit_pipeline(
    enc: &mut Encoder,
    lp: crate::ir::LoopId,
    body: &[Node],
    above: &[RowDesc],
    w_sum: bool,
) {
    let p = enc.d.get(lp);
    let tc = enc.a.tc(lp).avg.max(1.0);
    let uf = (p.uf.max(1) as f64).min(tc);
    let ii = pipeline_ii(enc, lp);

    // collect stmts under lp with their under-pipe reduction/serial rows
    struct Item {
        sid: StmtId,
        rows: Vec<RowDesc>,
    }
    let mut items: Vec<Item> = Vec::new();
    fn walk(enc: &Encoder, n: &Node, rows: Vec<RowDesc>, items: &mut Vec<Item>) {
        match n {
            Node::Stmt(s) => items.push(Item { sid: s.id, rows }),
            Node::Loop(l) => {
                let info = enc.a.deps.loop_info(l.id);
                let tc = enc.a.tc(l.id).avg.max(1.0);
                let uf = (enc.d.get(l.id).uf.max(1) as f64).min(tc);
                let mut rows = rows.clone();
                if info.reduction {
                    rows.push(RowDesc {
                        tc,
                        uf,
                        above_par: false,
                        above_seq: false,
                        under_red: true,
                    });
                } else if info.serializing {
                    rows.push(RowDesc {
                        tc,
                        uf: 1.0,
                        above_par: false,
                        above_seq: true, // serial factor inside IL
                        under_red: false,
                    });
                } else {
                    // parallel under-pipe loop: the unrolled part is pure
                    // replication (mcu), the remainder `tc/uf` iterates
                    // serially inside the body — an above_par row captures
                    // both (factor tc/uf, mcu uf); fully unrolled ⇒ 1
                    rows.push(RowDesc {
                        tc,
                        uf,
                        above_par: true,
                        above_seq: false,
                        under_red: false,
                    });
                }
                for c in &l.body {
                    walk(enc, c, rows.clone(), items);
                }
            }
        }
    }
    for n in body {
        walk(enc, n, Vec::new(), &mut items);
    }

    // independence among the collected statements: when the pipeline body
    // splits into > 1 dependence component the per-statement IL terms
    // overlap (max), so route them to the max set — the safe-under
    // approximation again
    let mut stmt_w_sum = w_sum;
    {
        let n = items.len();
        let mut comp: Vec<usize> = (0..n).collect();
        fn find(c: &mut Vec<usize>, i: usize) -> usize {
            if c[i] != i {
                let r = find(c, c[i]);
                c[i] = r;
            }
            c[i]
        }
        for i in 0..n {
            for j in i + 1..n {
                if enc.a.deps.stmts_dependent(items[i].sid, items[j].sid) {
                    let (ri, rj) = (find(&mut comp, i), find(&mut comp, j));
                    if ri != rj {
                        comp[ri] = rj;
                    }
                }
            }
        }
        let mut roots: Vec<usize> = (0..n).map(|i| find(&mut comp, i)).collect();
        roots.sort_unstable();
        roots.dedup();
        if roots.len() > 1 {
            stmt_w_sum = false;
        }
    }

    // per-stmt units
    for it in &items {
        let s = enc.k.stmt(it.sid);
        let red_op = enc.a.deps.reductions_of(it.sid).map(|(_, op)| op).next();
        let has_tree = it.rows.iter().any(|r| r.under_red);
        let (il_base, il_red) = if has_tree {
            // split chain: reduction op charged per tree level
            let mut base = 0f64;
            let mut red = 0f64;
            let mut charged = false;
            for &op in &s.chain {
                let c = enc.dev.op_costs(enc.k.dtype, op).latency as f64;
                if Some(op) == red_op && !charged {
                    red = c;
                    charged = true;
                } else {
                    base += c;
                }
            }
            if !charged {
                red = base.max(1.0);
                base = 0.0;
            }
            (base, red)
        } else {
            (stmt_chain(enc, it.sid), 0.0)
        };
        let mut rows = above.to_vec();
        rows.extend(it.rows.iter().copied());
        // the pipelined loop's own partial unroll replicates units too
        if uf > 1.0 {
            rows.push(RowDesc {
                tc,
                uf,
                above_par: false,
                above_seq: false,
                under_red: false,
            });
        }
        let dsp = stmt_dsp(enc, it.sid);
        // stmt units carry the pipeline II for DSP sharing (Eq 11's /II);
        // with pipe_tc = pipe_uf = 1 the ramp term stays zero, so latency
        // is unaffected
        enc.emit_unit(
            &rows,
            il_base.max(if il_red > 0.0 { 0.0 } else { 1.0 }),
            il_red,
            ii,
            1.0,
            1.0,
            dsp,
            stmt_w_sum,
        );
    }

    // ramp unit: II × (TC/UF − 1), scaled by the above factors; its ii
    // participates in DSP sharing via its own dsp_base = 0
    enc.emit_unit(above, 0.0, 0.0, ii, tc, uf, 0.0, w_sum);
}

fn collect_stmts(n: &Node) -> Vec<StmtId> {
    match n {
        Node::Stmt(s) => vec![s.id],
        Node::Loop(l) => l.body.iter().flat_map(collect_stmts).collect(),
    }
}

fn stmt_chain(enc: &Encoder, sid: StmtId) -> f64 {
    let s = enc.k.stmt(sid);
    if s.chain.is_empty() {
        return 1.0;
    }
    s.chain
        .iter()
        .map(|&op| enc.dev.op_costs(enc.k.dtype, op).latency as f64)
        .sum::<f64>()
        .max(1.0)
}

fn stmt_dsp(enc: &Encoder, sid: StmtId) -> f64 {
    enc.k
        .stmt(sid)
        .ops
        .iter()
        .map(|&(op, c)| c as f64 * enc.dev.op_costs(enc.k.dtype, op).dsp as f64)
        .sum()
}

fn pipeline_ii(enc: &Encoder, lp: crate::ir::LoopId) -> f64 {
    let info = enc.a.deps.loop_info(lp);
    let mut ii = 1.0f64;
    if info.reduction {
        if let Some(op) = info.reduction_op {
            ii = ii.max(enc.dev.op_costs(enc.k.dtype, op).latency as f64);
        }
    }
    if info.serializing {
        let d = info.min_distance.unwrap_or(1).max(1) as f64;
        let max_chain = enc
            .k
            .loop_meta(lp)
            .stmts
            .iter()
            .map(|&s| {
                let st = enc.k.stmt(s);
                if st.chain.is_empty() {
                    1.0
                } else {
                    st.chain
                        .iter()
                        .map(|&op| enc.dev.op_costs(enc.k.dtype, op).latency as f64)
                        .sum()
                }
            })
            .fold(1.0f64, f64::max);
        ii = ii.max((max_chain / d).ceil());
    }
    ii
}

/// Reference evaluation of the feature formula — semantically identical to
/// the Pallas kernel / jnp oracle; the artifact's outputs must match this
/// to 1e-6 relative.
pub fn eval_features(f: &DesignFeatures) -> (f64, f64) {
    let mut lat_sum = 0f64;
    let mut lat_max = 0f64;
    let mut dsp_max = 0f64;
    for u in 0..Abi::UNITS {
        let unit = &f.units[u * Abi::G..(u + 1) * Abi::G];
        if unit[7] == 0.0 {
            continue;
        }
        let (il_base, il_red, ii, pipe_tc, pipe_uf, dsp_base, w_sum) = (
            unit[0], unit[1], unit[2], unit[3], unit[4], unit[5], unit[6],
        );
        let mut above = 1f64;
        let mut tree = 1f64;
        let mut mcu = 1f64;
        for l in 0..Abi::LOOPS {
            let row = &f.loops[(u * Abi::LOOPS + l) * Abi::F..(u * Abi::LOOPS + l + 1) * Abi::F];
            if row[5] == 0.0 {
                continue;
            }
            let (tc, uf) = (row[0], row[1].max(1.0));
            if row[2] != 0.0 {
                above *= tc / uf;
            }
            if row[3] != 0.0 {
                above *= tc;
            }
            if row[4] != 0.0 {
                tree *= (tc / uf) * (uf.log2().ceil()).max(1.0);
            }
            mcu *= uf;
        }
        let il = il_base + il_red * tree;
        let lat = above * (il + ii * (pipe_tc / pipe_uf - 1.0).max(0.0));
        if w_sum != 0.0 {
            lat_sum += lat;
        } else {
            lat_max = lat_max.max(lat);
        }
        dsp_max = dsp_max.max(dsp_base * mcu / ii.max(1.0));
    }
    (lat_sum + lat_max, dsp_max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks;
    use crate::ir::{DType, LoopId};
    

    fn setup(name: &str) -> (Kernel, Analysis, Device) {
        let k = benchmarks::build(name, benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        (k, a, Device::u200())
    }

    #[test]
    fn encodes_all_small_benchmarks() {
        for name in benchmarks::ALL {
            if name == "cnn" {
                continue; // encoded at its single (medium) size below
            }
            let (k, a, dev) = setup(name);
            let d = Design::empty(&k);
            let f = encode_design(&k, &a, &dev, &d);
            assert!(f.is_some(), "{name} must fit the ABI");
        }
        let k = benchmarks::build("cnn", benchmarks::Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let f = encode_design(&k, &a, &Device::u200(), &Design::empty(&k));
        assert!(f.is_some(), "cnn must fit the ABI");
    }

    #[test]
    fn features_lower_bound_vs_precise_model() {
        // the encoded formula must stay ≤ the precise recursive model
        // (it under-approximates at independent components)
        for name in ["gemm", "2mm", "bicg", "atax", "mvt", "gesummv"] {
            let (k, a, dev) = setup(name);
            for uf in [1u64, 2] {
                let mut d = Design::empty(&k);
                if uf > 1 {
                    d.get_mut(LoopId(0)).uf = uf;
                }
                let f = encode_design(&k, &a, &dev, &d).unwrap();
                let (lat, _dsp) = eval_features(&f);
                let precise = crate::model::evaluate(&k, &a, &dev, &d);
                assert!(
                    lat <= precise.total_cycles * 1.02 + 1.0,
                    "{name} uf={uf}: features {lat} > precise {}",
                    precise.total_cycles
                );
                // and not absurdly loose
                assert!(
                    lat >= precise.total_cycles * 0.2,
                    "{name} uf={uf}: features {lat} ≪ precise {}",
                    precise.total_cycles
                );
            }
        }
    }

    #[test]
    fn ramp_unit_matches_pipeline_formula() {
        let (k, a, dev) = setup("gemm");
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(3)).pipeline = true;
        d.get_mut(LoopId(3)).uf = 2;
        let f = encode_design(&k, &a, &dev, &d).unwrap();
        let (lat, _) = eval_features(&f);
        let precise = crate::model::evaluate(&k, &a, &dev, &d);
        let rel = (lat - precise.total_cycles).abs() / precise.total_cycles;
        assert!(rel < 0.05, "features {lat} vs precise {}", precise.total_cycles);
    }

    #[test]
    fn dsp_scales_with_unroll() {
        let (k, a, dev) = setup("gemm");
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(3)).pipeline = true;
        let f1 = encode_design(&k, &a, &dev, &d).unwrap();
        let (_, dsp1) = eval_features(&f1);
        d.get_mut(LoopId(3)).uf = 10;
        let f10 = encode_design(&k, &a, &dev, &d).unwrap();
        let (_, dsp10) = eval_features(&f10);
        assert!(dsp10 >= dsp1 * 8.0, "dsp {dsp1} -> {dsp10}");
    }

    #[test]
    fn design_pragma_change_changes_encoding() {
        let (k, a, dev) = setup("gemm");
        let d1 = Design::empty(&k);
        let mut d2 = Design::empty(&k);
        d2.get_mut(LoopId(0)).uf = 4;
        let f1 = encode_design(&k, &a, &dev, &d1).unwrap();
        let f2 = encode_design(&k, &a, &dev, &d2).unwrap();
        assert_ne!(f1.loops, f2.loops);
    }
}
