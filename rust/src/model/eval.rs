//! Pure-Rust evaluation of the analytical lower-bound model.
//!
//! The recursion mirrors the Section 4.1 template:
//!
//! ```text
//! I_l(X)  = ceil(II_l * (TC_l/UF_l - ispip_l)) ⊙ X     (pipelined: +, else: ×)
//! C_l(Xs) = max(Xs) when independent, Σ Xs otherwise
//! SL_l(S) = straight-line lower bound (critical path vs work/resources)
//! ```
//!
//! with the Merlin/Vitis auto-optimizations of Section 3.1 applied first:
//! innermost loops not under an explicit pipeline are auto-pipelined, loops
//! under a pipeline are fully unrolled (parallel loops) or tree-reduced
//! (reduction loops, Theorem 4.7), and coarse-grained replication applies
//! only to non-reduction, non-serializing loops (Theorem 4.11).

use crate::hls::Device;
use crate::ir::{Kernel, LoopId, Node, Stmt, StmtId};
use crate::poly::Analysis;
use crate::pragma::Design;
use crate::util::ceil_log2;

/// Model output for one design.
#[derive(Clone, Debug)]
pub struct ModelResult {
    /// Computation latency lower bound, cycles (Theorem 4.15).
    pub comp_cycles: f64,
    /// Communication latency lower bound, cycles (Theorem 4.14).
    pub comm_cycles: f64,
    /// `comp + comm` (Theorem 4.16: no compute/transfer overlap).
    pub total_cycles: f64,
    /// Optimistic DSP usage, `R_used^min` (Theorem 4.12 / Eq 11).
    pub dsp: f64,
    /// On-chip bytes required for cached arrays (Eq 12).
    pub onchip_bytes: f64,
    /// Optimistic LUT usage — the Eq 11 recurrence over the device's LUT
    /// op costs. Advisory: reported on Pareto fronts and budgeted by the
    /// `system` allocator, **not** part of [`ModelResult::feasible`]
    /// (the paper's feasibility model is DSP/BRAM-only).
    pub lut: f64,
    /// Max per-array partitioning factor implied by the UFs (Eq 13).
    pub max_partitioning: u64,
    /// All resource constraints satisfied (DSP, on-chip bytes,
    /// partitioning — LUT deliberately excluded, as in the paper).
    pub feasible: bool,
    /// Worst achieved II across pipelined regions (reporting).
    pub worst_ii: f64,
}

impl ModelResult {
    /// Throughput implied by the latency bound.
    pub fn gflops(&self, analysis: &Analysis, device: &Device) -> f64 {
        analysis.gflops(self.total_cycles, device.freq_hz)
    }
}

struct Ctx<'a> {
    k: &'a Kernel,
    a: &'a Analysis,
    dev: &'a Device,
    d: &'a Design,
    worst_ii: f64,
}

/// Evaluate the lower bound for `design` on `kernel`.
pub fn evaluate(k: &Kernel, a: &Analysis, dev: &Device, d: &Design) -> ModelResult {
    let mut ctx = Ctx {
        k,
        a,
        dev,
        d,
        worst_ii: 1.0,
    };

    // --- computation latency (Theorem 4.15) -------------------------------
    let mut comp_cycles = compose(&mut ctx, &k.roots);

    // Theorem 4.4 work bound: with R_o = DSP_total/DSP(o) units of type o,
    // no schedule finishes before #L(o)·LO(o)/R_o cycles. This floors the
    // whole-program latency regardless of the pragma configuration.
    let mut work_floor = 0f64;
    for op in crate::ir::OpKind::ALL {
        let c = dev.op_costs(k.dtype, op);
        if c.dsp == 0 {
            continue; // LUT-implemented (div): not DSP-bounded
        }
        let total_ops: f64 = k
            .stmts()
            .map(|s| s.op_count(op) as f64 * a.stmt_iters[s.id.0 as usize])
            .sum();
        work_floor = work_floor
            .max(total_ops * c.latency as f64 * c.dsp as f64 / dev.dsp_total as f64);
    }
    comp_cycles = comp_cycles.max(work_floor);

    // --- communication latency (Theorem 4.14) -----------------------------
    // Lower bound: every array transferred exactly once (perfect reuse),
    // inputs in parallel across DRAM banks (max), then outputs (max).
    let mut in_max = 0f64;
    let mut out_max = 0f64;
    for arr in &k.arrays {
        let cyc = dev.transfer_cycles(arr.footprint_bytes(k.dtype));
        if arr.dir.is_live_in() {
            in_max = in_max.max(cyc);
        }
        if arr.dir.is_live_out() {
            out_max = out_max.max(cyc);
        }
    }
    let comm_cycles = in_max + out_max;

    // --- resources ---------------------------------------------------------
    let dsp = dsp_usage(&ctx);
    let lut = lut_usage(&ctx);
    let onchip_bytes = onchip_usage(&ctx);
    let max_partitioning = k
        .arrays
        .iter()
        .map(|arr| d.partitioning(k, arr.id))
        .max()
        .unwrap_or(1);

    let feasible = dsp <= dev.dsp_total as f64
        && onchip_bytes <= dev.onchip_bytes as f64
        && max_partitioning <= dev.max_array_partition;

    ModelResult {
        comp_cycles,
        comm_cycles,
        total_cycles: comp_cycles + comm_cycles,
        dsp,
        onchip_bytes,
        lut,
        max_partitioning,
        feasible,
        worst_ii: ctx.worst_ii,
    }
}

/// Per-nest latency breakdown used by the NLP solver's branch-and-bound
/// (objective separability across loop nests).
#[derive(Clone, Debug)]
pub struct NestBreakdown {
    /// Latency of each top-level nest (in `Kernel::nest_roots()` order).
    pub per_nest: Vec<f64>,
    /// Communication constant (Theorem 4.14).
    pub comm: f64,
    /// True when top-level nests compose by sum (dependent), false when
    /// independent (max-combine, e.g. mvt's two products).
    pub sum_combine: bool,
}

impl NestBreakdown {
    /// Combine per-nest latencies (sum when dependent, max when independent).
    pub fn total(&self) -> f64 {
        let c = if self.sum_combine {
            self.per_nest.iter().sum::<f64>()
        } else {
            self.per_nest.iter().cloned().fold(0.0, f64::max)
        };
        c + self.comm
    }
}

/// Compute per-nest latencies for `d` (same semantics as [`evaluate`],
/// decomposed by top-level loop).
pub fn nest_latencies(k: &Kernel, a: &Analysis, dev: &Device, d: &Design) -> NestBreakdown {
    let mut ctx = Ctx {
        k,
        a,
        dev,
        d,
        worst_ii: 1.0,
    };
    let per_nest: Vec<f64> = k
        .roots
        .iter()
        .map(|n| lat_node(&mut ctx, n))
        .collect();
    let mut in_max = 0f64;
    let mut out_max = 0f64;
    for arr in &k.arrays {
        let cyc = dev.transfer_cycles(arr.footprint_bytes(k.dtype));
        if arr.dir.is_live_in() {
            in_max = in_max.max(cyc);
        }
        if arr.dir.is_live_out() {
            out_max = out_max.max(cyc);
        }
    }
    NestBreakdown {
        per_nest,
        comm: in_max + out_max,
        sum_combine: top_scope_sum_combine(k, a),
    }
}

/// Whether the top-level nests form a single dependence component (sum).
pub fn top_scope_sum_combine(k: &Kernel, a: &Analysis) -> bool {
    let sets: Vec<Vec<StmtId>> = k.roots.iter().map(collect_stmts).collect();
    let n = sets.len();
    if n <= 1 {
        return true;
    }
    let mut comp: Vec<usize> = (0..n).collect();
    fn find(c: &mut Vec<usize>, i: usize) -> usize {
        if c[i] != i {
            let r = find(c, c[i]);
            c[i] = r;
        }
        c[i]
    }
    for i in 0..n {
        for j in i + 1..n {
            let dep = sets[i]
                .iter()
                .any(|&s1| sets[j].iter().any(|&s2| a.deps.stmts_dependent(s1, s2)));
            if dep {
                let (ri, rj) = (find(&mut comp, i), find(&mut comp, j));
                if ri != rj {
                    comp[ri] = rj;
                }
            }
        }
    }
    let mut roots: Vec<usize> = (0..n).map(|i| find(&mut comp, i)).collect();
    roots.sort_unstable();
    roots.dedup();
    roots.len() == 1
}

/// The `C` operator over sibling nodes: independent siblings take the max
/// (they may execute concurrently in the best case — lower bound), dependent
/// siblings are summed. Dependence between subtrees = any statement pair in
/// dependence.
fn compose(ctx: &mut Ctx, nodes: &[Node]) -> f64 {
    if nodes.is_empty() {
        return 0.0;
    }
    let lats: Vec<f64> = nodes.iter().map(|n| lat_node(ctx, n)).collect();
    let stmt_sets: Vec<Vec<StmtId>> = nodes.iter().map(|n| collect_stmts(n)).collect();
    // union-find over sibling indices by dependence
    let n = nodes.len();
    let mut comp: Vec<usize> = (0..n).collect();
    fn find(c: &mut Vec<usize>, i: usize) -> usize {
        if c[i] != i {
            let r = find(c, c[i]);
            c[i] = r;
        }
        c[i]
    }
    for i in 0..n {
        for j in i + 1..n {
            let dep = stmt_sets[i].iter().any(|&s1| {
                stmt_sets[j]
                    .iter()
                    .any(|&s2| ctx.a.deps.stmts_dependent(s1, s2))
            });
            if dep {
                let (ri, rj) = (find(&mut comp, i), find(&mut comp, j));
                if ri != rj {
                    comp[ri] = rj;
                }
            }
        }
    }
    // dependent components: sum; across components: max
    let mut sums: std::collections::BTreeMap<usize, f64> = Default::default();
    for i in 0..n {
        let r = find(&mut comp, i);
        *sums.entry(r).or_insert(0.0) += lats[i];
    }
    sums.values().cloned().fold(0.0f64, f64::max)
}

fn collect_stmts(n: &Node) -> Vec<StmtId> {
    match n {
        Node::Stmt(s) => vec![s.id],
        Node::Loop(l) => l.body.iter().flat_map(collect_stmts).collect(),
    }
}

/// Latency of one node above any pipeline.
fn lat_node(ctx: &mut Ctx, n: &Node) -> f64 {
    match n {
        Node::Stmt(s) => stmt_chain_latency(ctx, s),
        Node::Loop(l) => {
            let p = ctx.d.get(l.id);
            let info = ctx.a.deps.loop_info(l.id).clone();
            let tc = ctx.a.tc(l.id).avg.max(1.0);
            let innermost = ctx.k.loop_meta(l.id).innermost;
            if p.pipeline || innermost {
                // explicitly pipelined, or auto-pipelined innermost
                // (Section 3.1: Vitis auto-pipelines innermost loops)
                pipe_lat(ctx, l.id, &l.body)
            } else if info.reduction || info.serializing {
                // sequential loop (Definition 4.10); reductions cannot be
                // coarse-grain replicated (Theorem 4.11 precondition)
                tc * compose(ctx, &l.body)
            } else {
                // coarse-grained replication (Theorem 4.11):
                // floor(TC/UF) iterations of the replicated body
                let uf = p.uf.max(1) as f64;
                (tc / uf).max(1.0) * compose(ctx, &l.body)
            }
        }
    }
}

/// Pipelined region latency (Theorems 4.8/4.9):
/// `IL + II * (TC/UF - 1)`, where IL is the fully-unrolled body latency.
fn pipe_lat(ctx: &mut Ctx, lp: LoopId, body: &[Node]) -> f64 {
    let p = ctx.d.get(lp);
    let tc = ctx.a.tc(lp).avg.max(1.0);
    let uf = (p.uf.max(1) as f64).min(tc);
    let il = unrolled_body_latency(ctx, lp, body);
    let mut ii = pipeline_ii(ctx, lp);
    // a serializing pipelined loop's recurrence spans its whole body:
    // iteration i+d cannot start before iteration i's body completes
    // (Gauss-Seidel sweeps) — RecMII = delay/distance with delay = IL
    let info = ctx.a.deps.loop_info(lp);
    if info.serializing {
        let d = info.min_distance.unwrap_or(1).max(1) as f64;
        ii = ii.max((il / d).ceil());
    }
    ctx.worst_ii = ctx.worst_ii.max(ii);
    il + ii * (tc / uf - 1.0).max(0.0)
}

/// Minimal II of the pipelined loop `lp` (Section 4.2.3): `RecMII` from the
/// carried recurrences of statements under `lp`; `ResMII` assumed 1.
fn pipeline_ii(ctx: &Ctx, lp: LoopId) -> f64 {
    let info = ctx.a.deps.loop_info(lp);
    let mut ii = 1.0f64;
    // reduction recurrence: II >= IL(red op)
    if info.reduction {
        if let Some(op) = info.reduction_op {
            ii = ii.max(ctx.dev.op_costs(ctx.k.dtype, op).latency as f64);
        }
    }
    // constant-distance recurrence: II >= ceil(delay / distance)
    if info.serializing {
        let d = info.min_distance.unwrap_or(1).max(1) as f64;
        // delay: the carried statement's op-chain latency
        let max_chain = ctx
            .k
            .loop_meta(lp)
            .stmts
            .iter()
            .map(|&s| stmt_chain_latency_raw(ctx, ctx.k.stmt(s)))
            .fold(1.0f64, f64::max);
        ii = ii.max((max_chain / d).ceil());
    }
    ii
}

/// Latency of the fully-unrolled region under a pipelined loop `lp`
/// (the `SL` term): statements are collected with their tree-reduction
/// factors; independent statements overlap (max), dependent ones chain
/// (sum) — Section 5.4's `IL` term.
fn unrolled_body_latency(ctx: &mut Ctx, lp: LoopId, body: &[Node]) -> f64 {
    // collect leaf statements with two factors from the loops above them
    // (strictly under lp): the tree-reduction factor (multiplies only the
    // reduction op — Theorem 4.7) and the serial factor from
    // order-enforcing loops (multiplies the whole replicated chain: such a
    // loop unrolled in hardware chains its iterations back-to-back)
    let mut items: Vec<(StmtId, f64, f64)> = Vec::new();
    fn walk(
        ctx: &Ctx,
        n: &Node,
        tree_factor: f64,
        serial_factor: f64,
        items: &mut Vec<(StmtId, f64, f64)>,
    ) {
        match n {
            Node::Stmt(s) => items.push((s.id, tree_factor, serial_factor)),
            Node::Loop(l) => {
                let info = ctx.a.deps.loop_info(l.id);
                let tc = ctx.a.tc(l.id).avg.max(1.0);
                let uf = (ctx.d.get(l.id).uf.max(1) as f64).min(tc);
                let (tf, sf) = if info.reduction {
                    // Theorem 4.7: (TC/UF) tree passes of depth log2(UF)
                    ((tc / uf) * (ceil_log2(uf as u64) as f64).max(1.0), 1.0)
                } else if info.serializing {
                    (1.0, tc)
                } else {
                    // parallel loop: the unrolled part replicates (no
                    // latency), the rest iterates serially inside the
                    // pipeline body — factor 1 only when fully unrolled
                    // (Eq 15's intended configuration)
                    (1.0, (tc / uf).max(1.0))
                };
                for c in &l.body {
                    walk(ctx, c, tree_factor * tf, serial_factor * sf, items);
                }
            }
        }
    }
    for n in body {
        walk(ctx, n, 1.0, 1.0, &mut items);
    }
    if items.is_empty() {
        return 1.0;
    }

    // per-stmt latency: serial × (non-reduction chain + red-op × tree)
    let lats: Vec<f64> = items
        .iter()
        .map(|&(sid, tf, sf)| stmt_unrolled_latency(ctx, sid, tf) * sf)
        .collect();

    // dependence components over the collected statements
    let n = items.len();
    let mut comp: Vec<usize> = (0..n).collect();
    fn find(c: &mut Vec<usize>, i: usize) -> usize {
        if c[i] != i {
            let r = find(c, c[i]);
            c[i] = r;
        }
        c[i]
    }
    for i in 0..n {
        for j in i + 1..n {
            if ctx.a.deps.stmts_dependent(items[i].0, items[j].0) {
                let (ri, rj) = (find(&mut comp, i), find(&mut comp, j));
                if ri != rj {
                    comp[ri] = rj;
                }
            }
        }
    }
    let mut sums: std::collections::BTreeMap<usize, f64> = Default::default();
    for i in 0..n {
        let r = find(&mut comp, i);
        *sums.entry(r).or_insert(0.0) += lats[i];
    }
    let il = sums.values().cloned().fold(0.0f64, f64::max);
    let _ = lp;
    il.max(1.0)
}

/// One statement's latency inside the unrolled pipeline body:
/// the non-reduction part of its op chain runs once (instances are
/// concurrent), the reduction op runs `red_factor` times (tree levels ×
/// sequential passes).
fn stmt_unrolled_latency(ctx: &Ctx, sid: StmtId, red_factor: f64) -> f64 {
    let s = ctx.k.stmt(sid);
    if s.chain.is_empty() {
        return 1.0; // init/copy statements: >= 1 cycle
    }
    // identify the reduction op (last additive/associative op of the chain)
    let red_op = ctx
        .a
        .deps
        .reductions_of(sid)
        .map(|(_, op)| op)
        .next();
    let mut lat = 0f64;
    let mut red_charged = false;
    for &op in &s.chain {
        let c = ctx.dev.op_costs(ctx.k.dtype, op).latency as f64;
        if Some(op) == red_op && !red_charged && red_factor > 1.0 {
            lat += c * red_factor;
            red_charged = true;
        } else {
            lat += c;
        }
    }
    if red_factor > 1.0 && !red_charged {
        // reduction factor applies even if op kinds collide oddly
        lat *= red_factor;
    }
    lat.max(1.0)
}

/// Op-chain latency of one statement iteration (≥ 1 cycle).
fn stmt_chain_latency(ctx: &Ctx, s: &Stmt) -> f64 {
    stmt_chain_latency_raw(ctx, s)
}

fn stmt_chain_latency_raw(ctx: &Ctx, s: &Stmt) -> f64 {
    if s.chain.is_empty() {
        return 1.0;
    }
    s.chain
        .iter()
        .map(|&op| ctx.dev.op_costs(ctx.k.dtype, op).latency as f64)
        .sum::<f64>()
        .max(1.0)
}

/// Optimistic DSP usage (Theorem 4.12 / Eq 11): per nest, independent
/// statement components need concurrent units (sum) while sequential ones
/// can share (max); nests execute one after another (max over nests);
/// pipeline sharing divides by II.
fn dsp_usage(ctx: &Ctx) -> f64 {
    unit_usage(ctx, |c| c.dsp)
}

/// Optimistic LUT usage: the identical Eq 11 recurrence evaluated over
/// the device's per-operator LUT costs (so Div, DSP-free, shows up here).
/// Advisory only — never gates single-kernel feasibility.
fn lut_usage(ctx: &Ctx) -> f64 {
    unit_usage(ctx, |c| c.lut)
}

/// The shared Eq 11 recurrence behind [`dsp_usage`]/[`lut_usage`],
/// parameterized by which [`OpCosts`] column counts as the shared unit.
fn unit_usage(ctx: &Ctx, unit: fn(&crate::hls::OpCosts) -> u64) -> f64 {
    let k = ctx.k;
    let mut worst = 0f64;
    for root in k.nest_roots() {
        let stmts = &k.loop_meta(root).stmts;
        if stmts.is_empty() {
            continue;
        }
        // components by dependence
        let n = stmts.len();
        let mut comp: Vec<usize> = (0..n).collect();
        fn find(c: &mut Vec<usize>, i: usize) -> usize {
            if c[i] != i {
                let r = find(c, c[i]);
                c[i] = r;
            }
            c[i]
        }
        for i in 0..n {
            for j in i + 1..n {
                if ctx.a.deps.stmts_dependent(stmts[i], stmts[j]) {
                    let (ri, rj) = (find(&mut comp, i), find(&mut comp, j));
                    if ri != rj {
                        comp[ri] = rj;
                    }
                }
            }
        }
        let mut per_comp: std::collections::BTreeMap<usize, f64> = Default::default();
        for (idx, &sid) in stmts.iter().enumerate() {
            let mcu: f64 = k
                .stmt_meta(sid)
                .nest
                .iter()
                .map(|&l| {
                    let tc = ctx.a.tc(l).avg.max(1.0);
                    (ctx.d.get(l).uf.max(1) as f64).min(tc)
                })
                .product();
            let s = k.stmt(sid);
            let units_one: f64 = s
                .ops
                .iter()
                .map(|&(op, c)| c as f64 * unit(&ctx.dev.op_costs(k.dtype, op)) as f64)
                .sum();
            // pipeline sharing: units reused across II cycles
            let ii = ctx
                .d
                .pipeline_above(k, *k.stmt_meta(sid).nest.last().unwrap())
                .map(|lp| pipeline_ii(ctx, lp))
                .unwrap_or(1.0);
            let need = units_one * mcu / ii.max(1.0);
            let r = find(&mut comp, idx);
            let e = per_comp.entry(r).or_insert(0.0);
            *e = (*e).max(need);
        }
        let nest_units: f64 = per_comp.values().sum();
        worst = worst.max(nest_units);
    }
    worst
}

/// On-chip bytes for cached arrays (Eq 12). Merlin caches each array at the
/// outermost position; `tile` factors shrink the cached extent of the
/// dimensions their loop indexes.
fn onchip_usage(ctx: &Ctx) -> f64 {
    let k = ctx.k;
    let mut total = 0f64;
    for arr in &k.arrays {
        // per dim: width = full extent, scaled by tile/TC for loops tiled
        let mut per_dim: Vec<f64> = arr.dims.iter().map(|&d| d as f64).collect();
        for s in k.stmts() {
            for (acc, _) in k.stmt_accesses(s.id) {
                if acc.array != arr.id {
                    continue;
                }
                for (d, idx) in acc.indices.iter().enumerate() {
                    for l in idx.loops() {
                        let p = ctx.d.get(l);
                        let tc = ctx.a.tc(l).max.max(1);
                        if p.tile > 1 && p.tile < tc {
                            let scale = p.tile as f64 / tc as f64;
                            per_dim[d] = per_dim[d].min(arr.dims[d] as f64 * scale);
                        }
                    }
                }
            }
        }
        let elems: f64 = per_dim.iter().product();
        let bytes = elems * (k.dtype.bits() as f64 / 8.0);
        // arrays larger than Merlin's working tile are strip-mined /
        // streamed rather than cached whole
        total += bytes.min(ctx.dev.working_tile_bytes() as f64);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks;
    use crate::ir::DType;
    

    fn setup(
        name: &str,
    ) -> (Kernel, Analysis, Device) {
        let k = benchmarks::build(name, benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        (k, a, Device::u200())
    }

    #[test]
    fn empty_design_sequential_latency() {
        let (k, a, dev) = setup("gemm");
        let d = Design::empty(&k);
        let r = evaluate(&k, &a, &dev, &d);
        assert!(r.feasible);
        // sequential-ish: auto-pipelined innermost only; latency must be at
        // least #iterations of the dominant nest
        let min_iters = 60.0 * 80.0; // i × k pipeline starts
        assert!(r.comp_cycles >= min_iters, "{}", r.comp_cycles);
        assert!(r.comm_cycles > 0.0);
        assert!(r.total_cycles > r.comp_cycles);
    }

    #[test]
    fn unrolling_reduces_latency_monotonically() {
        let (k, a, dev) = setup("gemm");
        // pipeline j1 (innermost, LoopId 3) and unroll it progressively
        let mut prev = f64::INFINITY;
        for uf in [1u64, 2, 5, 10, 35, 70] {
            let mut d = Design::empty(&k);
            d.get_mut(LoopId(3)).pipeline = true;
            d.get_mut(LoopId(3)).uf = uf;
            let r = evaluate(&k, &a, &dev, &d);
            assert!(
                r.comp_cycles <= prev * 1.0001,
                "uf={uf}: {} > prev {prev}",
                r.comp_cycles
            );
            prev = r.comp_cycles;
        }
    }

    #[test]
    fn reduction_ii_bounds_pipeline() {
        let (k, a, dev) = setup("gemm");
        // pipeline k (reduction loop, LoopId 2): II >= IL(add) = 4
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(2)).pipeline = true;
        let r = evaluate(&k, &a, &dev, &d);
        assert!(r.worst_ii >= 4.0, "II {} must cover fadd latency", r.worst_ii);
    }

    #[test]
    fn parallel_pipeline_achieves_ii_1() {
        let (k, a, dev) = setup("gemm");
        // pipeline j1 (parallel innermost): II = 1
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(3)).pipeline = true;
        let r = evaluate(&k, &a, &dev, &d);
        assert_eq!(r.worst_ii, 1.0);
    }

    #[test]
    fn coarse_grain_scales_outer() {
        let (k, a, dev) = setup("gemm");
        let mut d1 = Design::empty(&k);
        d1.get_mut(LoopId(3)).pipeline = true;
        let r1 = evaluate(&k, &a, &dev, &d1);
        // replicate the i loop 4×
        let mut d4 = d1.clone();
        d4.get_mut(LoopId(0)).uf = 4;
        let r4 = evaluate(&k, &a, &dev, &d4);
        let ratio = r1.comp_cycles / r4.comp_cycles;
        assert!(
            (3.0..=4.5).contains(&ratio),
            "coarse 4x replication should ~4x compute: ratio {ratio}"
        );
        // and require ~4x the DSPs
        assert!(r4.dsp >= r1.dsp * 2.0);
    }

    #[test]
    fn tree_reduction_term_present() {
        let (k, a, dev) = setup("gemm");
        // pipeline i; k and j1 under it fully unrolled → tree over k
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(0)).pipeline = true;
        d.get_mut(LoopId(1)).uf = 70;
        d.get_mut(LoopId(2)).uf = 80;
        d.get_mut(LoopId(3)).uf = 70;
        let r = evaluate(&k, &a, &dev, &d);
        // IL must include log2(80)=7 tree levels of fadd (4 cycles) plus
        // the pipeline ramp over the 60 i-iterations
        assert!(
            r.comp_cycles >= 7.0 * 4.0 + 59.0,
            "{}",
            r.comp_cycles
        );
        // massive partitioning needed
        assert!(r.max_partitioning > crate::hls::Device::u200().max_array_partition);
        assert!(!r.feasible);
    }

    #[test]
    fn seidel_stays_sequential() {
        let (k, a, dev) = setup("seidel-2d");
        // unrolling pragmas must not reduce the serial latency floor
        let d0 = Design::empty(&k);
        let r0 = evaluate(&k, &a, &dev, &d0);
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(1)).uf = 2; // i: serializing → no coarse grain
        let r = evaluate(&k, &a, &dev, &d);
        assert!(
            r.comp_cycles >= r0.comp_cycles * 0.99,
            "serializing loop must not speed up: {} vs {}",
            r.comp_cycles,
            r0.comp_cycles
        );
    }

    #[test]
    fn comm_lower_bound_matches_paper_example() {
        // §4.2.8: transferring A (N×M f32) costs N*M/16 cycles
        let k = benchmarks::kernel_bicg(2100, 1900, DType::F32);
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let d = Design::empty(&k);
        let r = evaluate(&k, &a, &dev, &d);
        let expect_in = (2100.0 * 1900.0) / 16.0; // A dominates inputs
        let expect_out = 2100.0f64.max(1900.0) / 16.0; // s, q outputs
        assert!(
            (r.comm_cycles - (expect_in + expect_out)).abs() / expect_in < 0.01,
            "comm {} vs {}",
            r.comm_cycles,
            expect_in + expect_out
        );
    }

    #[test]
    fn infeasible_when_dsp_exhausted() {
        let (k, a, dev) = setup("gemm");
        let mut d = Design::empty(&k);
        // fully unroll everything → DSP explosion
        d.get_mut(LoopId(0)).uf = 60;
        d.get_mut(LoopId(1)).uf = 70;
        d.get_mut(LoopId(2)).uf = 80;
        d.get_mut(LoopId(3)).uf = 70;
        let r = evaluate(&k, &a, &dev, &d);
        assert!(r.dsp > dev.dsp_total as f64);
        assert!(!r.feasible);
    }
}
