//! Consumer 3's input type: a pragma configuration with holes.
//!
//! A [`PartialDesign`] assigns some pragmas and leaves the rest free;
//! [`BoundModel::lower_bound`](super::BoundModel::lower_bound) relaxes the
//! free ones to their Eq 1/2/8 interval hull and propagates, yielding a
//! latency no completion of the partial configuration can beat — the
//! paper's partial-configuration pruning primitive for DSE.

use crate::ir::LoopId;
use crate::pragma::{Design, LoopPragma};

/// A partially assigned pragma configuration. `None` entries are free.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartialDesign {
    /// Per-loop `UF` assignment (`None` = free).
    pub uf: Vec<Option<u64>>,
    /// Per-loop `tile` assignment (`None` = free).
    pub tile: Vec<Option<u64>>,
    /// Per-loop `pipeline` assignment (`None` = free).
    pub pipeline: Vec<Option<bool>>,
    /// Partitioning rung of the subspace under consideration: free `UF`s
    /// on array-indexing loops are additionally capped by this value
    /// (`u64::MAX` = unconstrained). See `BoundModel::boxes`.
    pub uf_cap: u64,
}

impl PartialDesign {
    /// Everything free — the whole design space of the kernel.
    pub fn free(n_loops: usize) -> PartialDesign {
        PartialDesign {
            uf: vec![None; n_loops],
            tile: vec![None; n_loops],
            pipeline: vec![None; n_loops],
            uf_cap: u64::MAX,
        }
    }

    /// Everything assigned — the degenerate partial for a complete design
    /// (its lower bound is the exact model value).
    pub fn from_design(d: &Design) -> PartialDesign {
        PartialDesign {
            uf: d.pragmas.iter().map(|p| Some(p.uf)).collect(),
            tile: d.pragmas.iter().map(|p| Some(p.tile)).collect(),
            pipeline: d.pragmas.iter().map(|p| Some(p.pipeline)).collect(),
            uf_cap: u64::MAX,
        }
    }

    /// Number of loops this partial design spans.
    pub fn n_loops(&self) -> usize {
        self.uf.len()
    }

    /// Pin loop `l`'s unroll factor.
    pub fn assign_uf(&mut self, l: LoopId, v: u64) -> &mut Self {
        self.uf[l.0 as usize] = Some(v);
        self
    }

    /// Pin loop `l`'s tile factor.
    pub fn assign_tile(&mut self, l: LoopId, v: u64) -> &mut Self {
        self.tile[l.0 as usize] = Some(v);
        self
    }

    /// Pin loop `l`'s pipeline flag.
    pub fn assign_pipeline(&mut self, l: LoopId, on: bool) -> &mut Self {
        self.pipeline[l.0 as usize] = Some(on);
        self
    }

    /// Builder-style partitioning-rung restriction.
    pub fn with_uf_cap(mut self, cap: u64) -> PartialDesign {
        self.uf_cap = cap;
        self
    }

    /// Number of still-free pragma slots (over all three kinds).
    pub fn free_slots(&self) -> usize {
        self.uf.iter().filter(|x| x.is_none()).count()
            + self.tile.iter().filter(|x| x.is_none()).count()
            + self.pipeline.iter().filter(|x| x.is_none()).count()
    }

    /// Every slot pinned (the bound is then the exact model value).
    pub fn is_complete(&self) -> bool {
        self.free_slots() == 0
    }

    /// The complete [`Design`], when nothing is free.
    pub fn to_design(&self) -> Option<Design> {
        if !self.is_complete() {
            return None;
        }
        Some(Design {
            pragmas: (0..self.n_loops())
                .map(|i| LoopPragma {
                    uf: self.uf[i].unwrap(),
                    tile: self.tile[i].unwrap(),
                    pipeline: self.pipeline[i].unwrap(),
                })
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;

    #[test]
    fn roundtrip_complete_design() {
        let k = crate::benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(2)).pipeline = true;
        d.get_mut(LoopId(3)).uf = 4;
        let p = PartialDesign::from_design(&d);
        assert!(p.is_complete());
        assert_eq!(p.to_design().unwrap(), d);
    }

    #[test]
    fn free_partial_is_incomplete() {
        let mut p = PartialDesign::free(4);
        assert!(!p.is_complete());
        assert_eq!(p.free_slots(), 12);
        p.assign_uf(LoopId(0), 2);
        assert_eq!(p.free_slots(), 11);
        assert!(p.to_design().is_none());
    }
}
