//! The symbolic bound-model IR — **one model, three consumers**.
//!
//! The paper's central architectural claim is that a *single* analytical
//! lower-bound model serves three roles: exact scoring of complete
//! designs, the objective/constraints of the NLP (Eqs 1–15), and latency
//! lower bounds for pruning *partial* pragma configurations during DSE.
//! This module makes that claim a first-class API:
//!
//! * [`expr`] — the expression IR: constants, per-loop unknowns
//!   `UF_l`/`tile_l`/`pip_l`, arithmetic/lattice/predicate operators, a
//!   hash-consed [`Pool`](expr::Pool) whose tape is topologically ordered
//!   by construction, and two linear-pass evaluators (concrete f64 and
//!   inclusion-sound intervals).
//! * [`build`] — [`BoundModel`]: built **once per kernel** from
//!   `ir` + `poly::Analysis` by transliterating the `model::eval`
//!   recursion, carrying the latency objective, the resource
//!   expressions, the Eqs 6/8/10–13 [`Constraint`] values, and the
//!   per-loop unknown domains.
//! * [`compile`] — consumer 1: [`BoundModel::compile`] flattens the model
//!   into the allocation-free [`CompiledModel`] batch evaluator that
//!   replaces the recursion on the DSE hot path
//!   (`CompiledModel::evaluate_batch`, and the structure-of-arrays lane
//!   kernel `CompiledModel::evaluate_batch_soa` — [`LANE_WIDTH`] designs
//!   per tape pass, bit-identical to the scalar path).
//! * [`constraint`] — consumer 2: `NlpProblem` is a thin view over the
//!   shared constraint objects; [`Violation`]s come from walking the
//!   shared [`Constraint`] values, and the solver's relaxation bounds come from
//!   interval propagation over the same expressions.
//! * [`partial`] — consumer 3: [`PartialDesign`] +
//!   [`BoundModel::lower_bound`] evaluate the model with unassigned
//!   pragmas relaxed to their interval extremes, giving any engine an
//!   achievable-latency pruning primitive for whole subspaces
//!   (`dse --prune-bound`, `Explorer::lower_bound`).
//!
//! Parity invariant (property-tested in `tests/property_model_sym.rs`):
//! for every complete design, the compiled tape reproduces
//! `model::evaluate` (resources bit-for-bit, latency to the last ulp) and
//! `BoundModel::check` reproduces the legacy `NlpProblem` violation set
//! exactly. Soundness invariant: `lower_bound(partial)` never exceeds the
//! model value of any completion of the partial configuration.

pub mod build;
pub mod compile;
pub mod constraint;
pub mod expr;
pub mod partial;

pub use build::{BoundModel, VarDomain};
pub use compile::{CompiledModel, CompiledResult, EvalScratch, SoaScratch};
pub use constraint::{Constraint, Violation};
pub use expr::{ExprId, Interval, Pool, SymNode, VarBox, LANE_WIDTH};
pub use partial::PartialDesign;

// Thread-safety contract: one model build serves the parallel solver's
// whole worker team behind `Arc`, so every shared model type must stay
// `Send + Sync` (plain data, no interior mutability). Compile-time
// enforced here so a future `Cell`/`Rc` field fails the build instead of
// un-Sync-ing `NlpProblem` at a distance.
#[allow(dead_code)]
fn _assert_models_are_thread_safe() {
    fn ok<T: Send + Sync>() {}
    ok::<BoundModel>();
    ok::<CompiledModel>();
    ok::<EvalScratch>();
    ok::<SoaScratch>();
    ok::<PartialDesign>();
    ok::<Constraint>();
}
