//! The symbolic expression pool: a hash-consed DAG of arithmetic over the
//! per-loop pragma unknowns.
//!
//! Grammar (see DESIGN.md §7 for the lowering map):
//!
//! ```text
//! e ::= c                                  constants (f64)
//!     | UF_l | tile_l | pip_l              per-loop unknowns
//!     | e + e | e - e | e * e | e / e      arithmetic
//!     | min(e, e) | max(e, e)              lattice ops
//!     | ceil(e)                            integer ceiling
//!     | treelog(e)                         max(1, ceil(log2(trunc(e))))
//!     | e > e | e < e | e ∧ e              0/1-valued predicates
//!     | select(e, e, e)                    branch on a 0/1 predicate
//! ```
//!
//! Nodes are interned ([`Pool`]): building the same subexpression twice
//! yields the same [`ExprId`], so the pool doubles as a flattened,
//! topologically-ordered evaluation tape (children always precede
//! parents). Both evaluators — concrete ([`eval_concrete`]) and interval
//! ([`eval_interval`]) — are single linear passes over that tape.
//!
//! Interval semantics: every operator is evaluated with standard inclusion
//! rules (4-corner multiply/divide, hull on `select` with an undecided
//! predicate), so for any assignment drawn from the input boxes the
//! concrete value of every node lies inside its interval. This is the
//! soundness property `BoundModel::lower_bound` relies on.

use crate::pragma::Design;
use crate::util::ceil_log2;
use std::collections::HashMap;

/// Lane width of the batched (structure-of-arrays) evaluators: both the
/// concrete SoA tape kernel (`CompiledModel::evaluate_batch_soa`) and the
/// laned interval evaluator ([`eval_interval_lanes`]) process this many
/// designs/boxes per tape pass, with values laid out node-major
/// (`vals[node * LANE_WIDTH + lane]`) so each operator becomes a
/// straight-line loop over lanes the compiler can auto-vectorize.
pub const LANE_WIDTH: usize = 8;

/// Index of an interned node in its [`Pool`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExprId(pub u32);

/// One interned operator node. `Const` stores the f64 bit pattern so the
/// node is `Eq + Hash` for interning.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SymNode {
    /// Constant (f64 bits, for `Eq + Hash` interning).
    Const(u64),
    /// `UF_l`: the raw `parallel factor` unknown of loop `l`.
    Uf(u32),
    /// `tile_l`: the raw `tile factor` unknown of loop `l`.
    Tile(u32),
    /// `pip_l ∈ {0,1}`: the `pipeline` unknown of loop `l`.
    Pip(u32),
    /// `a + b`
    Add(ExprId, ExprId),
    /// `a - b`
    Sub(ExprId, ExprId),
    /// `a * b`
    Mul(ExprId, ExprId),
    /// `a / b` (divisors are positive in this model).
    Div(ExprId, ExprId),
    /// `min(a, b)`
    Min(ExprId, ExprId),
    /// `max(a, b)`
    Max(ExprId, ExprId),
    /// Integer ceiling.
    Ceil(ExprId),
    /// `max(1, ceil_log2(trunc(x)))` — the tree-reduction depth factor of
    /// Theorem 4.7, matching `eval`'s `(ceil_log2(uf as u64) as f64).max(1.)`.
    TreeLog(ExprId),
    /// `(a > b) as f64` (0.0 or 1.0).
    Gt(ExprId, ExprId),
    /// `(a < b) as f64`.
    Lt(ExprId, ExprId),
    /// Logical conjunction of two 0/1 values.
    And(ExprId, ExprId),
    /// `if cond != 0 { then } else { other }`.
    Select(ExprId, ExprId, ExprId),
}

/// Hash-consing arena of [`SymNode`]s.
#[derive(Clone, Debug, Default)]
pub struct Pool {
    nodes: Vec<SymNode>,
    memo: HashMap<SymNode, ExprId>,
}

impl Pool {
    /// An empty pool.
    pub fn new() -> Pool {
        Pool::default()
    }

    /// The interned nodes in topological (tape) order.
    pub fn nodes(&self) -> &[SymNode] {
        &self.nodes
    }

    /// Number of interned nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when nothing is interned.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    fn intern(&mut self, n: SymNode) -> ExprId {
        if let Some(&id) = self.memo.get(&n) {
            return id;
        }
        let id = ExprId(self.nodes.len() as u32);
        self.nodes.push(n);
        self.memo.insert(n, id);
        id
    }

    /// Drop the interning memo once construction is done: consumers only
    /// walk `nodes()`, and the memo would otherwise double the model's
    /// resident size and clone cost.
    pub fn seal(&mut self) {
        self.memo = HashMap::new();
    }

    /// Intern the constant `v`.
    pub fn cf(&mut self, v: f64) -> ExprId {
        self.intern(SymNode::Const(v.to_bits()))
    }
    /// Intern loop `l`'s `UF` unknown.
    pub fn uf(&mut self, l: u32) -> ExprId {
        self.intern(SymNode::Uf(l))
    }
    /// Intern loop `l`'s `tile` unknown.
    pub fn tile(&mut self, l: u32) -> ExprId {
        self.intern(SymNode::Tile(l))
    }
    /// Intern loop `l`'s `pipeline` unknown.
    pub fn pip(&mut self, l: u32) -> ExprId {
        self.intern(SymNode::Pip(l))
    }
    /// Intern `a + b`.
    pub fn add(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::Add(a, b))
    }
    /// Intern `a - b`.
    pub fn sub(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::Sub(a, b))
    }
    /// Intern `a * b`.
    pub fn mul(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::Mul(a, b))
    }
    /// Intern `a / b`.
    pub fn div(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::Div(a, b))
    }
    /// Intern `min(a, b)`.
    pub fn min(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::Min(a, b))
    }
    /// Intern `max(a, b)`.
    pub fn max(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::Max(a, b))
    }
    /// Intern `ceil(a)`.
    pub fn ceil(&mut self, a: ExprId) -> ExprId {
        self.intern(SymNode::Ceil(a))
    }
    /// Intern the Theorem 4.7 tree-depth factor of `a`.
    pub fn treelog(&mut self, a: ExprId) -> ExprId {
        self.intern(SymNode::TreeLog(a))
    }
    /// Intern the 0/1 predicate `a > b`.
    pub fn gt(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::Gt(a, b))
    }
    /// Intern the 0/1 predicate `a < b`.
    pub fn lt(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::Lt(a, b))
    }
    /// Intern the 0/1 conjunction `a ∧ b`.
    pub fn and(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.intern(SymNode::And(a, b))
    }
    /// Intern `if c != 0 { t } else { e }`.
    pub fn select(&mut self, c: ExprId, t: ExprId, e: ExprId) -> ExprId {
        self.intern(SymNode::Select(c, t, e))
    }

    /// `max(x, c)` with a fresh constant — the most common clamp.
    pub fn max_c(&mut self, x: ExprId, c: f64) -> ExprId {
        let k = self.cf(c);
        self.max(x, k)
    }
    /// `min(x, c)`.
    pub fn min_c(&mut self, x: ExprId, c: f64) -> ExprId {
        let k = self.cf(c);
        self.min(x, k)
    }
}

// shared by the scalar evaluators here and the SoA lane kernel in
// compile.rs — bit-identity across the two depends on both calling the
// exact same function
#[inline]
pub(crate) fn treelog_f(x: f64) -> f64 {
    let t = x.trunc().max(1.0) as u64;
    (ceil_log2(t) as f64).max(1.0)
}

/// Evaluate every node of `nodes` on a concrete [`Design`], writing node
/// values into `out` (resized as needed). A single linear pass: the tape
/// is topologically ordered by construction.
pub fn eval_concrete(nodes: &[SymNode], d: &Design, out: &mut Vec<f64>) {
    out.clear();
    out.resize(nodes.len(), 0.0);
    for (i, n) in nodes.iter().enumerate() {
        let v = match *n {
            SymNode::Const(bits) => f64::from_bits(bits),
            SymNode::Uf(l) => d.pragmas[l as usize].uf as f64,
            SymNode::Tile(l) => d.pragmas[l as usize].tile as f64,
            SymNode::Pip(l) => d.pragmas[l as usize].pipeline as u8 as f64,
            SymNode::Add(a, b) => out[a.0 as usize] + out[b.0 as usize],
            SymNode::Sub(a, b) => out[a.0 as usize] - out[b.0 as usize],
            SymNode::Mul(a, b) => out[a.0 as usize] * out[b.0 as usize],
            SymNode::Div(a, b) => out[a.0 as usize] / out[b.0 as usize],
            SymNode::Min(a, b) => out[a.0 as usize].min(out[b.0 as usize]),
            SymNode::Max(a, b) => out[a.0 as usize].max(out[b.0 as usize]),
            SymNode::Ceil(a) => out[a.0 as usize].ceil(),
            SymNode::TreeLog(a) => treelog_f(out[a.0 as usize]),
            SymNode::Gt(a, b) => (out[a.0 as usize] > out[b.0 as usize]) as u8 as f64,
            SymNode::Lt(a, b) => (out[a.0 as usize] < out[b.0 as usize]) as u8 as f64,
            SymNode::And(a, b) => {
                ((out[a.0 as usize] != 0.0) && (out[b.0 as usize] != 0.0)) as u8 as f64
            }
            SymNode::Select(c, t, e) => {
                if out[c.0 as usize] != 0.0 {
                    out[t.0 as usize]
                } else {
                    out[e.0 as usize]
                }
            }
        };
        out[i] = v;
    }
}

/// A closed interval `[lo, hi]` of f64 values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    /// Lower endpoint.
    pub lo: f64,
    /// Upper endpoint.
    pub hi: f64,
}

impl Interval {
    /// The degenerate interval `[v, v]`.
    pub fn point(v: f64) -> Interval {
        Interval { lo: v, hi: v }
    }
    /// The interval `[lo, hi]` (debug-asserts `lo <= hi`).
    pub fn new(lo: f64, hi: f64) -> Interval {
        debug_assert!(lo <= hi, "inverted interval [{lo}, {hi}]");
        Interval { lo, hi }
    }
    /// Whether `v` lies in the interval.
    pub fn contains(&self, v: f64) -> bool {
        self.lo <= v && v <= self.hi
    }
    fn hull(a: Interval, b: Interval) -> Interval {
        Interval {
            lo: a.lo.min(b.lo),
            hi: a.hi.max(b.hi),
        }
    }
    fn corners(a: Interval, b: Interval, f: impl Fn(f64, f64) -> f64) -> Interval {
        let c = [
            f(a.lo, b.lo),
            f(a.lo, b.hi),
            f(a.hi, b.lo),
            f(a.hi, b.hi),
        ];
        Interval {
            lo: c.iter().cloned().fold(f64::INFINITY, f64::min),
            hi: c.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

/// Per-loop unknown boxes for interval propagation.
#[derive(Clone, Copy, Debug)]
pub struct VarBox {
    /// Box of the `UF` unknown.
    pub uf: Interval,
    /// Box of the `tile` unknown.
    pub tile: Interval,
    /// Box of the `pipeline` unknown.
    pub pip: Interval,
}

// One node's interval rule, abstracted over how child intervals are
// fetched so the scalar ([`eval_interval`]) and laned
// ([`eval_interval_lanes`]) passes share it verbatim — lane-vs-scalar
// bit-identity holds by construction, not by parallel maintenance.
#[inline]
fn iv_node(n: &SymNode, boxes: &[VarBox], get: impl Fn(ExprId) -> Interval) -> Interval {
    match *n {
        SymNode::Const(bits) => Interval::point(f64::from_bits(bits)),
        SymNode::Uf(l) => boxes[l as usize].uf,
        SymNode::Tile(l) => boxes[l as usize].tile,
        SymNode::Pip(l) => boxes[l as usize].pip,
        SymNode::Add(a, b) => {
            let (a, b) = (get(a), get(b));
            Interval::new(a.lo + b.lo, a.hi + b.hi)
        }
        SymNode::Sub(a, b) => {
            let (a, b) = (get(a), get(b));
            Interval::new(a.lo - b.hi, a.hi - b.lo)
        }
        SymNode::Mul(a, b) => Interval::corners(get(a), get(b), |x, y| x * y),
        SymNode::Div(a, b) => {
            let (a, b) = (get(a), get(b));
            if b.lo <= 0.0 {
                // divisor interval touches zero (unreachable with the
                // current lowering, where every divisor is clamped
                // ≥ 1): widen to the sign-correct half-line/line so
                // inclusion still holds for any numerator
                if a.lo >= 0.0 {
                    Interval::new(0.0, f64::INFINITY)
                } else {
                    Interval::new(f64::NEG_INFINITY, f64::INFINITY)
                }
            } else {
                Interval::corners(a, b, |x, y| x / y)
            }
        }
        SymNode::Min(a, b) => {
            let (a, b) = (get(a), get(b));
            Interval::new(a.lo.min(b.lo), a.hi.min(b.hi))
        }
        SymNode::Max(a, b) => {
            let (a, b) = (get(a), get(b));
            Interval::new(a.lo.max(b.lo), a.hi.max(b.hi))
        }
        SymNode::Ceil(a) => {
            let a = get(a);
            Interval::new(a.lo.ceil(), a.hi.ceil())
        }
        SymNode::TreeLog(a) => {
            let a = get(a);
            Interval::new(treelog_f(a.lo), treelog_f(a.hi))
        }
        SymNode::Gt(a, b) => {
            let (a, b) = (get(a), get(b));
            if a.lo > b.hi {
                Interval::point(1.0)
            } else if a.hi <= b.lo {
                Interval::point(0.0)
            } else {
                Interval::new(0.0, 1.0)
            }
        }
        SymNode::Lt(a, b) => {
            let (a, b) = (get(a), get(b));
            if a.hi < b.lo {
                Interval::point(1.0)
            } else if a.lo >= b.hi {
                Interval::point(0.0)
            } else {
                Interval::new(0.0, 1.0)
            }
        }
        SymNode::And(a, b) => {
            let (a, b) = (get(a), get(b));
            let a1 = a.lo != 0.0 || a.hi != 0.0; // can be true
            let b1 = b.lo != 0.0 || b.hi != 0.0;
            let a0 = a.contains(0.0); // can be false
            let b0 = b.contains(0.0);
            match (a1 && b1, a0 || b0) {
                (true, false) => Interval::point(1.0),
                (false, _) => Interval::point(0.0),
                _ => Interval::new(0.0, 1.0),
            }
        }
        SymNode::Select(c, t, e) => {
            let c = get(c);
            if c.lo != 0.0 || c.hi != 0.0 {
                // predicate *may* hold
                if c.contains(0.0) {
                    Interval::hull(get(t), get(e))
                } else {
                    get(t)
                }
            } else {
                get(e)
            }
        }
    }
}

/// Evaluate every node over the per-loop boxes with inclusion-sound
/// interval rules. Division assumes a positive divisor (every divisor in
/// the lowered model is a trip count, a clamped unroll factor, or a
/// dependence distance, all ≥ 1); a divisor interval touching zero widens
/// to `[0, +inf]` defensively.
pub fn eval_interval(nodes: &[SymNode], boxes: &[VarBox], out: &mut Vec<Interval>) {
    out.clear();
    out.resize(nodes.len(), Interval::point(0.0));
    for (i, n) in nodes.iter().enumerate() {
        let v = iv_node(n, boxes, |e| out[e.0 as usize]);
        out[i] = v;
    }
}

/// Laned interval evaluation: [`LANE_WIDTH`] box sets propagated through
/// the tape in one pass, values node-major
/// (`out[node * LANE_WIDTH + lane]`). Each lane applies exactly the
/// scalar [`eval_interval`] rules (both delegate to the same per-node
/// helper), so per-lane results are bit-identical to scalar calls — this
/// is what lets `BoundModel::lower_bound_batch` replace per-partial
/// scalar passes without perturbing any pruning decision.
pub fn eval_interval_lanes(
    nodes: &[SymNode],
    boxes: &[&[VarBox]; LANE_WIDTH],
    out: &mut Vec<Interval>,
) {
    out.clear();
    out.resize(nodes.len() * LANE_WIDTH, Interval::point(0.0));
    for (i, n) in nodes.iter().enumerate() {
        for lane in 0..LANE_WIDTH {
            let v = iv_node(n, boxes[lane], |e| out[e.0 as usize * LANE_WIDTH + lane]);
            out[i * LANE_WIDTH + lane] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;
    use crate::pragma::Design;
    use crate::util::rng::Rng;

    fn d1(k: &crate::ir::Kernel, uf0: u64, pip0: bool) -> Design {
        let mut d = Design::empty(k);
        d.pragmas[0].uf = uf0;
        d.pragmas[0].pipeline = pip0;
        d
    }

    #[test]
    fn interning_dedups() {
        let mut p = Pool::new();
        let a = p.uf(0);
        let b = p.cf(2.0);
        let e1 = p.mul(a, b);
        let e2 = p.mul(a, b);
        assert_eq!(e1, e2);
        assert_eq!(p.len(), 3);
    }

    #[test]
    fn concrete_eval_matches_hand_formula() {
        let k = crate::benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let mut p = Pool::new();
        let uf = p.uf(0);
        let uf1 = p.max_c(uf, 1.0);
        let tc = p.cf(8.0);
        let per = p.div(tc, uf1);
        let lat = p.max_c(per, 1.0);
        let sel = {
            let pip = p.pip(0);
            let one = p.cf(1.0);
            p.select(pip, one, lat)
        };
        let mut out = Vec::new();
        eval_concrete(p.nodes(), &d1(&k, 4, false), &mut out);
        assert_eq!(out[sel.0 as usize], 2.0);
        eval_concrete(p.nodes(), &d1(&k, 4, true), &mut out);
        assert_eq!(out[sel.0 as usize], 1.0);
    }

    #[test]
    fn treelog_matches_eval_semantics() {
        let mut p = Pool::new();
        let uf = p.uf(0);
        let t = p.treelog(uf);
        let k = crate::benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let mut out = Vec::new();
        for (ufv, expect) in [(1u64, 1.0), (2, 1.0), (3, 2.0), (8, 3.0), (9, 4.0)] {
            eval_concrete(p.nodes(), &d1(&k, ufv, false), &mut out);
            assert_eq!(out[t.0 as usize], expect, "uf={ufv}");
        }
    }

    #[test]
    fn interval_contains_concrete_samples() {
        // randomized inclusion check on a small expression zoo
        let k = crate::benchmarks::kernel_gemm(16, 16, 16, DType::F32);
        let mut p = Pool::new();
        let uf = p.uf(0);
        let uf1 = p.max_c(uf, 1.0);
        let tile = p.tile(0);
        let pip = p.pip(0);
        let tc = p.cf(16.0);
        let ratio = p.div(tc, uf1);
        let ramp = {
            let one = p.cf(1.0);
            let s = p.sub(ratio, one);
            p.max_c(s, 0.0)
        };
        let tl = p.treelog(uf1);
        let cond = {
            let one = p.cf(1.0);
            let g = p.gt(tile, one);
            let l = p.lt(tile, tc);
            p.and(g, l)
        };
        let scaled = {
            let m = p.mul(ramp, tl);
            p.select(cond, m, ratio)
        };
        let root = p.select(pip, scaled, ramp);

        let boxes = vec![VarBox {
            uf: Interval::new(1.0, 16.0),
            tile: Interval::new(1.0, 16.0),
            pip: Interval::new(0.0, 1.0),
        }];
        let mut iv = Vec::new();
        eval_interval(p.nodes(), &boxes, &mut iv);

        let mut rng = Rng::new(0xfeed);
        let mut out = Vec::new();
        for _ in 0..500 {
            let mut d = Design::empty(&k);
            d.pragmas[0].uf = rng.range(1, 17);
            d.pragmas[0].tile = rng.range(1, 17);
            d.pragmas[0].pipeline = rng.chance(0.5);
            eval_concrete(p.nodes(), &d, &mut out);
            for (i, &v) in out.iter().enumerate() {
                assert!(
                    iv[i].contains(v),
                    "node {i} value {v} outside [{}, {}] (root {})",
                    iv[i].lo,
                    iv[i].hi,
                    root.0
                );
            }
        }
    }

    #[test]
    fn laned_interval_eval_matches_scalar_per_lane() {
        // same expression zoo as the inclusion test; each lane gets a
        // different box set and must reproduce the scalar pass bit-for-bit
        let mut p = Pool::new();
        let uf = p.uf(0);
        let uf1 = p.max_c(uf, 1.0);
        let tile = p.tile(0);
        let pip = p.pip(0);
        let tc = p.cf(16.0);
        let ratio = p.div(tc, uf1);
        let ramp = {
            let one = p.cf(1.0);
            let s = p.sub(ratio, one);
            p.max_c(s, 0.0)
        };
        let tl = p.treelog(uf1);
        let cond = {
            let one = p.cf(1.0);
            let g = p.gt(tile, one);
            let l = p.lt(tile, tc);
            p.and(g, l)
        };
        let scaled = {
            let m = p.mul(ramp, tl);
            p.select(cond, m, ratio)
        };
        let _root = p.select(pip, scaled, ramp);

        let lane_boxes: Vec<Vec<VarBox>> = (0..LANE_WIDTH)
            .map(|lane| {
                let hi = (lane + 1) as f64 * 2.0;
                vec![VarBox {
                    uf: Interval::new(1.0, hi),
                    tile: Interval::new(1.0, hi),
                    pip: if lane % 2 == 0 {
                        Interval::new(0.0, 1.0)
                    } else {
                        Interval::point(1.0)
                    },
                }]
            })
            .collect();
        let refs: [&[VarBox]; LANE_WIDTH] = std::array::from_fn(|j| lane_boxes[j].as_slice());
        let mut laned = Vec::new();
        eval_interval_lanes(p.nodes(), &refs, &mut laned);

        let mut scalar = Vec::new();
        for (lane, boxes) in lane_boxes.iter().enumerate() {
            eval_interval(p.nodes(), boxes, &mut scalar);
            for (i, iv) in scalar.iter().enumerate() {
                let l = laned[i * LANE_WIDTH + lane];
                assert_eq!(iv.lo.to_bits(), l.lo.to_bits(), "node {i} lane {lane} lo");
                assert_eq!(iv.hi.to_bits(), l.hi.to_bits(), "node {i} lane {lane} hi");
            }
        }
    }

    #[test]
    fn fixed_boxes_collapse_to_points() {
        let mut p = Pool::new();
        let uf = p.uf(0);
        let tc = p.cf(12.0);
        let e = p.div(tc, uf);
        let boxes = vec![VarBox {
            uf: Interval::point(3.0),
            tile: Interval::point(1.0),
            pip: Interval::point(0.0),
        }];
        let mut iv = Vec::new();
        eval_interval(p.nodes(), &boxes, &mut iv);
        assert_eq!(iv[e.0 as usize], Interval::point(4.0));
    }
}
