//! Consumer 2's substrate: Eqs 6/8/10–13 as first-class constraint
//! values, and the shared violation reporting both `NlpProblem` and the
//! solver consume.
//!
//! The lowering map (paper Eq → [`Constraint`]):
//!
//! | Eq | Constraint | Carrier |
//! |----|------------|---------|
//! | 6  | `Divides`  | integer predicate on `UF_l` vs `TC_l` |
//! | 8  | `Distance` | integer cap `UF_l ≤ d_l` (emitted when `d_l > 1`) |
//! | 10/13 | `Partitioning` | symbolic per-array product, bound supplied at check time |
//! | 11 | `Dsp` | symbolic usage vs device total |
//! | 12 | `OnChip` | symbolic footprint vs device capacity |
//!
//! Eqs 1–5/7/9/14/15 are enforced *structurally* (candidate generation,
//! `Space`, `materialize`, Merlin-auto) and therefore have no residual
//! check-time constraint; see `nlp::formulation`'s table.

use super::build::BoundModel;
use super::compile::{CompiledModel, CompiledResult, EvalScratch};
use super::expr::ExprId;
use crate::pragma::Design;

/// One first-class constraint of the bound model. The constraint order in
/// `BoundModel::constraints` reproduces the legacy `NlpProblem::check`
/// report order (per-loop Eq 6 then Eq 8, per-array Eq 10/13, Eq 11,
/// Eq 12), which the model/NLP parity property test relies on.
#[derive(Clone, Debug)]
pub enum Constraint {
    /// Eq 6: `TC_l mod UF_l == 0`; unrolling requires a constant TC.
    Divides {
        l: u32,
        tc_max: u64,
        tc_constant: bool,
    },
    /// Eq 8: `UF_l ≤ dist` for a carried dependence of distance > 1.
    Distance { l: u32, dist: u64 },
    /// Eqs 10/13: array partitioning ≤ cap (cap = min(device, DSE rung),
    /// supplied at check time).
    Partitioning {
        /// Index into `kernel.arrays` / `CompiledModel` partition slots.
        array: usize,
        name: String,
        /// The symbolic partitioning product (in `BoundModel::pool`).
        expr: ExprId,
    },
    /// Eq 11: optimistic DSP usage ≤ device total.
    Dsp { expr: ExprId, budget: u64 },
    /// Eq 12: cached on-chip footprint ≤ device capacity.
    OnChip { expr: ExprId, budget: u64 },
}

/// A violated constraint on a concrete design. (Moved here from
/// `nlp::formulation`, which re-exports it: violations are now produced
/// by the shared constraint objects, not per-consumer checks.)
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// Eq 10/13: partitioning cap exceeded (array name, required, cap).
    Partitioning(String, u64, u64),
    /// Eq 11: DSP over budget (needed, available).
    Dsp(u64, u64),
    /// Eq 12: on-chip memory over budget (needed bytes, available).
    OnChip(u64, u64),
    /// Eq 6: UF does not divide TC (loop index, uf, tc).
    Divisibility(u32, u64, u64),
    /// Eq 8: UF above the carried-dependence cap.
    Dependence(u32, u64, u64),
}

impl BoundModel {
    /// Evaluate every constraint on a complete design; returns the
    /// violations in constraint order (empty = feasible NLP point).
    /// `cap` is the effective partitioning cap of the DSE step.
    pub fn check(
        &self,
        cm: &CompiledModel,
        scratch: &mut EvalScratch,
        d: &Design,
        cap: u64,
    ) -> Vec<Violation> {
        let mut out = Vec::new();
        let mut evaluated: Option<CompiledResult> = None;
        for c in &self.constraints {
            match c {
                Constraint::Divides {
                    l,
                    tc_max,
                    tc_constant,
                } => {
                    let uf = d.pragmas[*l as usize].uf;
                    if uf > 1 && (!tc_constant || tc_max % uf != 0) {
                        out.push(Violation::Divisibility(*l, uf, *tc_max));
                    }
                }
                Constraint::Distance { l, dist } => {
                    let uf = d.pragmas[*l as usize].uf;
                    if uf > *dist {
                        out.push(Violation::Dependence(*l, uf, *dist));
                    }
                }
                Constraint::Partitioning { array, name, .. } => {
                    if evaluated.is_none() {
                        evaluated = Some(cm.evaluate(d, scratch));
                    }
                    let part = cm.partitioning_of(scratch, *array);
                    if part > cap {
                        out.push(Violation::Partitioning(name.clone(), part, cap));
                    }
                }
                Constraint::Dsp { budget, .. } => {
                    if evaluated.is_none() {
                        evaluated = Some(cm.evaluate(d, scratch));
                    }
                    let dsp = evaluated.as_ref().unwrap().dsp;
                    if dsp > *budget as f64 {
                        out.push(Violation::Dsp(dsp as u64, *budget));
                    }
                }
                Constraint::OnChip { budget, .. } => {
                    if evaluated.is_none() {
                        evaluated = Some(cm.evaluate(d, scratch));
                    }
                    let oc = evaluated.as_ref().unwrap().onchip_bytes;
                    if oc > *budget as f64 {
                        out.push(Violation::OnChip(oc as u64, *budget));
                    }
                }
            }
        }
        out
    }

    /// Combined feasibility + objective with a single tape evaluation —
    /// the solver's leaf hot path. Returns `None` on the first violated
    /// constraint.
    pub fn check_objective(
        &self,
        cm: &CompiledModel,
        scratch: &mut EvalScratch,
        d: &Design,
        cap: u64,
    ) -> Option<f64> {
        // integer constraints first: no tape evaluation needed
        for c in &self.constraints {
            match c {
                Constraint::Divides {
                    l,
                    tc_max,
                    tc_constant,
                } => {
                    let uf = d.pragmas[*l as usize].uf;
                    if uf > 1 && (!tc_constant || tc_max % uf != 0) {
                        return None;
                    }
                }
                Constraint::Distance { l, dist } => {
                    if d.pragmas[*l as usize].uf > *dist {
                        return None;
                    }
                }
                _ => {}
            }
        }
        let r = cm.evaluate(d, scratch);
        for c in &self.constraints {
            match c {
                Constraint::Partitioning { array, .. } => {
                    if cm.partitioning_of(scratch, *array) > cap {
                        return None;
                    }
                }
                Constraint::Dsp { budget, .. } => {
                    if r.dsp > *budget as f64 {
                        return None;
                    }
                }
                Constraint::OnChip { budget, .. } => {
                    if r.onchip_bytes > *budget as f64 {
                        return None;
                    }
                }
                _ => {}
            }
        }
        Some(r.total_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks;
    use crate::hls::Device;
    use crate::ir::{DType, LoopId};
    use crate::poly::Analysis;

    fn setup(name: &str) -> (crate::ir::Kernel, Analysis, Device) {
        let k = benchmarks::build(name, benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        (k, a, Device::u200())
    }

    #[test]
    fn non_divisor_uf_flagged_by_shared_constraints() {
        let (k, a, dev) = setup("gemm");
        let bm = BoundModel::build(&k, &a, &dev);
        let cm = bm.compile();
        let mut scratch = cm.scratch();
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(0)).uf = 7; // 60 % 7 != 0
        let v = bm.check(&cm, &mut scratch, &d, u64::MAX);
        assert!(v
            .iter()
            .any(|v| matches!(v, Violation::Divisibility(0, 7, 60))));
    }

    #[test]
    fn feasible_empty_design_has_no_violations() {
        let (k, a, dev) = setup("gemm");
        let bm = BoundModel::build(&k, &a, &dev);
        let cm = bm.compile();
        let mut scratch = cm.scratch();
        let v = bm.check(&cm, &mut scratch, &Design::empty(&k), u64::MAX);
        assert!(v.is_empty(), "{v:?}");
        assert!(bm
            .check_objective(&cm, &mut scratch, &Design::empty(&k), u64::MAX)
            .is_some());
    }

    #[test]
    fn check_objective_rejects_what_check_flags() {
        let (k, a, dev) = setup("gemm");
        let bm = BoundModel::build(&k, &a, &dev);
        let cm = bm.compile();
        let mut scratch = cm.scratch();
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(0)).uf = 60;
        d.get_mut(LoopId(1)).uf = 70;
        d.get_mut(LoopId(2)).uf = 80;
        d.get_mut(LoopId(3)).uf = 70;
        assert!(!bm.check(&cm, &mut scratch, &d, u64::MAX).is_empty());
        assert!(bm
            .check_objective(&cm, &mut scratch, &d, u64::MAX)
            .is_none());
    }
}
