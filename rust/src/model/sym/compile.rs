//! Consumer 1: the flattened, allocation-free batch evaluator.
//!
//! [`BoundModel::compile`](super::BoundModel::compile) prunes the pool to
//! the nodes reachable from the result roots and re-numbers them into a
//! dense topologically-ordered tape. Evaluation is a single linear pass
//! writing into a caller-owned [`EvalScratch`]; `evaluate_batch` reuses
//! one scratch across the whole batch, so the per-design cost is the tape
//! walk alone — no recursion, no per-design allocation (the DSE hot path
//! the legacy `model::evaluate` recursion paid for with dozens of
//! temporary `Vec`s per call).
//!
//! On top of the scalar path sits the structure-of-arrays batch kernel
//! (`evaluate_batch_soa`): [`LANE_WIDTH`] designs share one tape pass
//! with values laid out node-major (`vals[node * LANE_WIDTH + lane]`), so
//! every operator is a straight-line loop over lanes — no per-design
//! dispatch overhead, and the lane loops auto-vectorize. Each lane
//! performs the *same* f64 operation sequence as [`eval_concrete`]
//! (`select` stays a per-lane conditional move, never an arithmetic
//! blend), so SoA results are bit-identical to the scalar evaluator; the
//! property suites assert this corpus-wide.

use super::build::BoundModel;
use super::expr::{eval_concrete, treelog_f, ExprId, SymNode, LANE_WIDTH};
use crate::pragma::Design;

// child-lane accessor into the already-written prefix of the SoA buffer
#[inline(always)]
fn lane(prev: &[f64], e: ExprId) -> &[f64] {
    &prev[e.0 as usize * LANE_WIDTH..][..LANE_WIDTH]
}

/// The flattened evaluator. Self-contained (owns its tape): cheap to
/// cache per kernel and to send across threads.
#[derive(Clone, Debug)]
pub struct CompiledModel {
    tape: Vec<SymNode>,
    comp: u32,
    comm: u32,
    total: u32,
    dsp: u32,
    onchip: u32,
    max_part: u32,
    /// Per-array partitioning slots, in kernel array order.
    partitions: Vec<u32>,
    dsp_total: u64,
    onchip_bytes: u64,
    max_array_partition: u64,
}

/// Reusable value buffer for tape evaluation.
#[derive(Clone, Debug, Default)]
pub struct EvalScratch {
    vals: Vec<f64>,
}

/// Reusable node-major lane buffer for the SoA batch kernel
/// (`vals[node * LANE_WIDTH + lane]`). One per worker thread: the solver
/// keeps one in each `WorkerScratch` so leaf scoring never allocates.
#[derive(Clone, Debug, Default)]
pub struct SoaScratch {
    vals: Vec<f64>,
}

/// The compiled counterpart of `model::ModelResult` (minus the II
/// reporting field, which only the recursive evaluator tracks).
#[derive(Clone, Copy, Debug)]
pub struct CompiledResult {
    /// Computation latency lower bound, cycles.
    pub comp_cycles: f64,
    /// Communication latency lower bound, cycles.
    pub comm_cycles: f64,
    /// `comp + comm` — the objective.
    pub total_cycles: f64,
    /// Optimistic DSP usage (Eq 11).
    pub dsp: f64,
    /// Cached on-chip bytes (Eq 12).
    pub onchip_bytes: f64,
    /// Max per-array partitioning factor (Eq 13).
    pub max_partitioning: u64,
    /// All resource constraints satisfied.
    pub feasible: bool,
}

impl CompiledModel {
    pub(super) fn from_model(m: &BoundModel) -> CompiledModel {
        let nodes = m.pool.nodes();
        let roots: Vec<ExprId> = [m.comp, m.comm, m.total, m.dsp, m.onchip, m.max_part]
            .into_iter()
            .chain(m.partitions.iter().map(|&(_, e)| e))
            .collect();

        // liveness: mark roots, then sweep the (topologically ordered)
        // tape backwards marking children
        let mut live = vec![false; nodes.len()];
        for r in &roots {
            live[r.0 as usize] = true;
        }
        fn mark(live: &mut [bool], e: ExprId) {
            live[e.0 as usize] = true;
        }
        for i in (0..nodes.len()).rev() {
            if !live[i] {
                continue;
            }
            match nodes[i] {
                SymNode::Const(_) | SymNode::Uf(_) | SymNode::Tile(_) | SymNode::Pip(_) => {}
                SymNode::Ceil(a) | SymNode::TreeLog(a) => mark(&mut live, a),
                SymNode::Add(a, b)
                | SymNode::Sub(a, b)
                | SymNode::Mul(a, b)
                | SymNode::Div(a, b)
                | SymNode::Min(a, b)
                | SymNode::Max(a, b)
                | SymNode::Gt(a, b)
                | SymNode::Lt(a, b)
                | SymNode::And(a, b) => {
                    mark(&mut live, a);
                    mark(&mut live, b);
                }
                SymNode::Select(c, t, e) => {
                    mark(&mut live, c);
                    mark(&mut live, t);
                    mark(&mut live, e);
                }
            }
        }

        // dense renumbering, preserving topological order
        let mut remap = vec![u32::MAX; nodes.len()];
        let mut tape = Vec::new();
        for (i, n) in nodes.iter().enumerate() {
            if !live[i] {
                continue;
            }
            let r = |e: ExprId| ExprId(remap[e.0 as usize]);
            let new = match *n {
                SymNode::Const(_) | SymNode::Uf(_) | SymNode::Tile(_) | SymNode::Pip(_) => *n,
                SymNode::Add(a, b) => SymNode::Add(r(a), r(b)),
                SymNode::Sub(a, b) => SymNode::Sub(r(a), r(b)),
                SymNode::Mul(a, b) => SymNode::Mul(r(a), r(b)),
                SymNode::Div(a, b) => SymNode::Div(r(a), r(b)),
                SymNode::Min(a, b) => SymNode::Min(r(a), r(b)),
                SymNode::Max(a, b) => SymNode::Max(r(a), r(b)),
                SymNode::Ceil(a) => SymNode::Ceil(r(a)),
                SymNode::TreeLog(a) => SymNode::TreeLog(r(a)),
                SymNode::Gt(a, b) => SymNode::Gt(r(a), r(b)),
                SymNode::Lt(a, b) => SymNode::Lt(r(a), r(b)),
                SymNode::And(a, b) => SymNode::And(r(a), r(b)),
                SymNode::Select(c, t, e) => SymNode::Select(r(c), r(t), r(e)),
            };
            remap[i] = tape.len() as u32;
            tape.push(new);
        }

        CompiledModel {
            tape,
            comp: remap[m.comp.0 as usize],
            comm: remap[m.comm.0 as usize],
            total: remap[m.total.0 as usize],
            dsp: remap[m.dsp.0 as usize],
            onchip: remap[m.onchip.0 as usize],
            max_part: remap[m.max_part.0 as usize],
            partitions: m
                .partitions
                .iter()
                .map(|&(_, e)| remap[e.0 as usize])
                .collect(),
            dsp_total: m.dsp_total,
            onchip_bytes: m.onchip_bytes,
            max_array_partition: m.max_array_partition,
        }
    }

    /// Tape length (for reporting / benches).
    pub fn n_instructions(&self) -> usize {
        self.tape.len()
    }

    /// A scratch buffer sized for this tape.
    pub fn scratch(&self) -> EvalScratch {
        EvalScratch {
            vals: Vec::with_capacity(self.tape.len()),
        }
    }

    /// Evaluate one design. Allocation-free when `scratch` has been used
    /// with this model before.
    pub fn evaluate(&self, d: &Design, scratch: &mut EvalScratch) -> CompiledResult {
        eval_concrete(&self.tape, d, &mut scratch.vals);
        let v = &scratch.vals;
        let dsp = v[self.dsp as usize];
        let onchip = v[self.onchip as usize];
        let max_partitioning = v[self.max_part as usize] as u64;
        CompiledResult {
            comp_cycles: v[self.comp as usize],
            comm_cycles: v[self.comm as usize],
            total_cycles: v[self.total as usize],
            dsp,
            onchip_bytes: onchip,
            max_partitioning,
            feasible: dsp <= self.dsp_total as f64
                && onchip <= self.onchip_bytes as f64
                && max_partitioning <= self.max_array_partition,
        }
    }

    /// Evaluate a batch, reusing one scratch across all designs.
    ///
    /// This is the scalar (array-of-structures) path: one tape pass per
    /// design. Kept as the baseline the benches compare
    /// [`evaluate_batch_soa`](Self::evaluate_batch_soa) against; hot
    /// callers should prefer the SoA path.
    pub fn evaluate_batch(&self, designs: &[Design]) -> Vec<CompiledResult> {
        let mut scratch = self.scratch();
        designs
            .iter()
            .map(|d| self.evaluate(d, &mut scratch))
            .collect()
    }

    /// A lane scratch sized for this tape.
    pub fn soa_scratch(&self) -> SoaScratch {
        SoaScratch {
            vals: Vec::with_capacity(self.tape.len() * LANE_WIDTH),
        }
    }

    /// Evaluate a batch through the structure-of-arrays kernel: one tape
    /// pass per [`LANE_WIDTH`] designs instead of one per design.
    /// Bit-identical to mapping [`evaluate`](Self::evaluate) over the
    /// batch (each lane runs the same f64 op sequence). Convenience
    /// wrapper that owns its scratch; hot loops should hold a
    /// [`SoaScratch`] and call
    /// [`evaluate_batch_soa_in`](Self::evaluate_batch_soa_in).
    pub fn evaluate_batch_soa(&self, designs: &[Design]) -> Vec<CompiledResult> {
        let mut scratch = self.soa_scratch();
        let mut out = Vec::new();
        self.evaluate_batch_soa_in(designs, &mut scratch, &mut out);
        out
    }

    /// Allocation-free SoA batch evaluation into caller-owned buffers
    /// (`out` is cleared first). Remainder chunks shorter than
    /// [`LANE_WIDTH`] pad the trailing lanes by replicating the last
    /// design; padded lanes are evaluated and discarded, never reported.
    pub fn evaluate_batch_soa_in(
        &self,
        designs: &[Design],
        scratch: &mut SoaScratch,
        out: &mut Vec<CompiledResult>,
    ) {
        out.clear();
        out.reserve(designs.len());
        let mut base = 0;
        while base < designs.len() {
            let live = LANE_WIDTH.min(designs.len() - base);
            let chunk: [&Design; LANE_WIDTH] =
                std::array::from_fn(|j| &designs[base + j.min(live - 1)]);
            self.eval_chunk(&chunk, &mut scratch.vals);
            for l in 0..live {
                out.push(self.result_of_lane(&scratch.vals, l));
            }
            base += live;
        }
    }

    // One SoA tape pass over a full chunk of LANE_WIDTH designs. Each
    // node writes its own LANE_WIDTH slot; `split_at_mut` separates the
    // already-computed child lanes (`prev`) from the slot being written
    // (`cur`) — legal because the tape is topologically ordered, and it
    // gives the compiler disjoint fixed-width slices to vectorize over.
    fn eval_chunk(&self, chunk: &[&Design; LANE_WIDTH], vals: &mut Vec<f64>) {
        vals.clear();
        vals.resize(self.tape.len() * LANE_WIDTH, 0.0);
        for (i, n) in self.tape.iter().enumerate() {
            let (prev, rest) = vals.split_at_mut(i * LANE_WIDTH);
            let cur = &mut rest[..LANE_WIDTH];
            match *n {
                SymNode::Const(bits) => cur.fill(f64::from_bits(bits)),
                SymNode::Uf(l) => {
                    for (j, c) in cur.iter_mut().enumerate() {
                        *c = chunk[j].pragmas[l as usize].uf as f64;
                    }
                }
                SymNode::Tile(l) => {
                    for (j, c) in cur.iter_mut().enumerate() {
                        *c = chunk[j].pragmas[l as usize].tile as f64;
                    }
                }
                SymNode::Pip(l) => {
                    for (j, c) in cur.iter_mut().enumerate() {
                        *c = chunk[j].pragmas[l as usize].pipeline as u8 as f64;
                    }
                }
                SymNode::Add(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = a[j] + b[j];
                    }
                }
                SymNode::Sub(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = a[j] - b[j];
                    }
                }
                SymNode::Mul(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = a[j] * b[j];
                    }
                }
                SymNode::Div(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = a[j] / b[j];
                    }
                }
                SymNode::Min(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = a[j].min(b[j]);
                    }
                }
                SymNode::Max(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = a[j].max(b[j]);
                    }
                }
                SymNode::Ceil(a) => {
                    let a = lane(prev, a);
                    for j in 0..LANE_WIDTH {
                        cur[j] = a[j].ceil();
                    }
                }
                SymNode::TreeLog(a) => {
                    let a = lane(prev, a);
                    for j in 0..LANE_WIDTH {
                        cur[j] = treelog_f(a[j]);
                    }
                }
                SymNode::Gt(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = (a[j] > b[j]) as u8 as f64;
                    }
                }
                SymNode::Lt(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = (a[j] < b[j]) as u8 as f64;
                    }
                }
                SymNode::And(a, b) => {
                    let (a, b) = (lane(prev, a), lane(prev, b));
                    for j in 0..LANE_WIDTH {
                        cur[j] = ((a[j] != 0.0) && (b[j] != 0.0)) as u8 as f64;
                    }
                }
                SymNode::Select(c, t, e) => {
                    // per-lane conditional select (a branchless cmov per
                    // lane after vectorization) — NOT an arithmetic blend
                    // like c*t + (1-c)*e, which would break bit-identity
                    // with the scalar evaluator for inf/NaN operands
                    let (c, t, e) = (lane(prev, c), lane(prev, t), lane(prev, e));
                    for j in 0..LANE_WIDTH {
                        cur[j] = if c[j] != 0.0 { t[j] } else { e[j] };
                    }
                }
            }
        }
    }

    // Read one lane's roots back out of the SoA buffer, applying the same
    // feasibility thresholds as the scalar `evaluate`.
    fn result_of_lane(&self, vals: &[f64], l: usize) -> CompiledResult {
        let at = |root: u32| vals[root as usize * LANE_WIDTH + l];
        let dsp = at(self.dsp);
        let onchip = at(self.onchip);
        let max_partitioning = at(self.max_part) as u64;
        CompiledResult {
            comp_cycles: at(self.comp),
            comm_cycles: at(self.comm),
            total_cycles: at(self.total),
            dsp,
            onchip_bytes: onchip,
            max_partitioning,
            feasible: dsp <= self.dsp_total as f64
                && onchip <= self.onchip_bytes as f64
                && max_partitioning <= self.max_array_partition,
        }
    }

    /// Partitioning of array `idx` (kernel array order) from the last
    /// `evaluate` into `scratch`.
    pub fn partitioning_of(&self, scratch: &EvalScratch, idx: usize) -> u64 {
        scratch.vals[self.partitions[idx] as usize] as u64
    }

    /// Number of per-array partitioning slots.
    pub fn n_arrays(&self) -> usize {
        self.partitions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks;
    use crate::hls::Device;
    use crate::ir::{DType, LoopId};
    use crate::model;
    use crate::poly::Analysis;

    #[test]
    fn compiled_matches_recursive_model_on_gemm() {
        let k = benchmarks::build("gemm", benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let bm = super::super::BoundModel::build(&k, &a, &dev);
        let cm = bm.compile();
        let mut scratch = cm.scratch();
        for (pipe, uf0, uf3) in [
            (None, 1, 1),
            (Some(3u32), 1, 10),
            (Some(2), 4, 1),
            (Some(0), 2, 70),
        ] {
            let mut d = crate::pragma::Design::empty(&k);
            if let Some(p) = pipe {
                d.get_mut(LoopId(p)).pipeline = true;
            }
            d.get_mut(LoopId(0)).uf = uf0;
            d.get_mut(LoopId(3)).uf = uf3;
            let r = cm.evaluate(&d, &mut scratch);
            let precise = model::evaluate(&k, &a, &dev, &d);
            let rel = (r.total_cycles - precise.total_cycles).abs()
                / precise.total_cycles.max(1.0);
            assert!(
                rel < 1e-9,
                "pipe={pipe:?} uf0={uf0} uf3={uf3}: {} vs {}",
                r.total_cycles,
                precise.total_cycles
            );
            assert_eq!(r.dsp, precise.dsp, "dsp mismatch");
            assert_eq!(r.onchip_bytes, precise.onchip_bytes);
            assert_eq!(r.max_partitioning, precise.max_partitioning);
            assert_eq!(r.feasible, precise.feasible);
        }
    }

    #[test]
    fn pruned_tape_is_smaller_than_pool() {
        let k = benchmarks::build("2mm", benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let bm = super::super::BoundModel::build(&k, &a, &Device::u200());
        let cm = bm.compile();
        assert!(cm.n_instructions() <= bm.pool.len());
        assert!(cm.n_instructions() > 0);
    }

    #[test]
    fn soa_batch_bit_identical_to_scalar_across_sizes() {
        // odd sizes exercise the remainder-lane padding path; 0 the
        // empty batch; 8/16 the full-chunk path
        let k = benchmarks::build("gemm", benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let bm = super::super::BoundModel::build(&k, &a, &Device::u200());
        let cm = bm.compile();
        let mut rng = crate::util::rng::Rng::new(0xa0a0);
        for n in [0usize, 1, 3, 7, 8, 9, 13, 16] {
            let designs: Vec<Design> = (0..n)
                .map(|_| {
                    let mut d = Design::empty(&k);
                    for p in &mut d.pragmas {
                        p.uf = rng.range(1, 33);
                        p.tile = rng.range(1, 17);
                        p.pipeline = rng.chance(0.5);
                    }
                    d
                })
                .collect();
            let soa = cm.evaluate_batch_soa(&designs);
            assert_eq!(soa.len(), designs.len(), "n={n}");
            let mut scratch = cm.scratch();
            for (i, (d, r)) in designs.iter().zip(&soa).enumerate() {
                let s = cm.evaluate(d, &mut scratch);
                assert_eq!(
                    s.total_cycles.to_bits(),
                    r.total_cycles.to_bits(),
                    "n={n} i={i} total"
                );
                assert_eq!(s.comp_cycles.to_bits(), r.comp_cycles.to_bits());
                assert_eq!(s.comm_cycles.to_bits(), r.comm_cycles.to_bits());
                assert_eq!(s.dsp.to_bits(), r.dsp.to_bits());
                assert_eq!(s.onchip_bytes.to_bits(), r.onchip_bytes.to_bits());
                assert_eq!(s.max_partitioning, r.max_partitioning);
                assert_eq!(s.feasible, r.feasible);
            }
        }
    }

    #[test]
    fn soa_scratch_is_reusable_across_batches() {
        let k = benchmarks::build("bicg", benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let bm = super::super::BoundModel::build(&k, &a, &Device::u200());
        let cm = bm.compile();
        let mut scratch = cm.soa_scratch();
        let mut out = Vec::new();
        let mut expect = cm.scratch();
        for uf in [1u64, 2, 4, 8] {
            let mut d = Design::empty(&k);
            d.get_mut(LoopId(0)).uf = uf;
            let designs = vec![d.clone(); 3];
            cm.evaluate_batch_soa_in(&designs, &mut scratch, &mut out);
            assert_eq!(out.len(), 3);
            let s = cm.evaluate(&d, &mut expect);
            for r in &out {
                assert_eq!(s.total_cycles.to_bits(), r.total_cycles.to_bits());
            }
        }
    }

    #[test]
    fn batch_matches_single_eval() {
        let k = benchmarks::build("bicg", benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let bm = super::super::BoundModel::build(&k, &a, &Device::u200());
        let cm = bm.compile();
        let mut designs = Vec::new();
        for uf in [1u64, 2, 4] {
            let mut d = crate::pragma::Design::empty(&k);
            d.get_mut(LoopId(0)).uf = uf;
            designs.push(d);
        }
        let batch = cm.evaluate_batch(&designs);
        let mut scratch = cm.scratch();
        for (d, r) in designs.iter().zip(&batch) {
            let single = cm.evaluate(d, &mut scratch);
            assert_eq!(single.total_cycles, r.total_cycles);
            assert_eq!(single.dsp, r.dsp);
        }
    }
}
