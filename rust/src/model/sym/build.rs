//! Lowering of the Section 4 recursion into the symbolic IR: one
//! [`BoundModel`] per kernel, built from `ir` + `poly::Analysis`.
//!
//! The builder is a *transliteration* of `model::eval`: every concrete
//! arithmetic step of the recursion becomes one pool node, in the same
//! order and associativity, with the pragma reads (`d.get(l).uf`,
//! `.tile`, `.pipeline`) replaced by the unknowns `UF_l` / `tile_l` /
//! `pip_l` and the pragma-dependent branches by `select` nodes. A design
//! plugged into the compiled tape therefore reproduces `model::evaluate`
//! exactly (bit-for-bit on the resource side, and to the last ulp on the
//! latency side — property-tested in `tests/property_model_sym.rs`).
//!
//! Structure-dependent decisions (dependence components, reduction /
//! serializing classification, innermost-ness) do **not** depend on the
//! pragmas, so they are resolved at build time, exactly as `eval` resolves
//! them per call.

use super::compile::CompiledModel;
use super::constraint::Constraint;
use super::expr::{ExprId, Interval, Pool, VarBox};
use super::partial::PartialDesign;
use crate::hls::Device;
use crate::ir::{Kernel, LoopId, Node, StmtId};
use crate::poly::Analysis;

/// Per-loop unknown bounds (the Eq 1/2/8 hull used for interval
/// relaxation). `uf_hi = 1` encodes "not unrollable" (non-constant trip
/// count, or a serializing non-reduction carried dependence).
#[derive(Clone, Copy, Debug)]
pub struct VarDomain {
    /// Upper bound of the `UF` unknown (1 = not unrollable).
    pub uf_hi: u64,
    /// Upper bound of the `tile` unknown.
    pub tile_hi: u64,
    /// Whether this loop indexes any array dimension — if so, `UF_l` is
    /// additionally capped by the partitioning rung during subspace
    /// relaxation (a UF above the cap forces some array's partitioning
    /// above the cap).
    pub indexes_array: bool,
}

/// The symbolic lower-bound model of one kernel: latency objective,
/// resource expressions, and the Eqs 1–15 constraint set, shared by the
/// three consumers (compiled exact scoring, NLP lowering, partial-config
/// interval bounds).
#[derive(Clone, Debug)]
pub struct BoundModel {
    /// Kernel name the model was built from.
    pub kernel: String,
    /// Number of per-loop unknown triples.
    pub n_loops: usize,
    /// The hash-consed expression arena (topological tape).
    pub pool: Pool,
    /// Computation latency (Theorem 4.15), including the work floor.
    pub comp: ExprId,
    /// Communication latency constant (Theorem 4.14).
    pub comm: ExprId,
    /// The objective: `comp + comm` (Theorem 4.16).
    pub total: ExprId,
    /// Optimistic DSP usage (Theorem 4.12 / Eq 11).
    pub dsp: ExprId,
    /// Cached on-chip bytes (Eq 12).
    pub onchip: ExprId,
    /// Max per-array partitioning (Eq 13).
    pub max_part: ExprId,
    /// Per-array partitioning expressions, in `kernel.arrays` order.
    pub partitions: Vec<(String, ExprId)>,
    /// Eqs 6/8/10–13 as first-class values, in the order the legacy
    /// `NlpProblem::check` reported them.
    pub constraints: Vec<Constraint>,
    /// Per-loop unknown domains (Eq 1/2/8 hulls).
    pub domains: Vec<VarDomain>,
    /// Device DSP budget (Eq 11 right-hand side).
    pub dsp_total: u64,
    /// Device on-chip byte budget (Eq 12 right-hand side).
    pub onchip_bytes: u64,
    /// Vitis per-array partition limit (Eq 13 cap).
    pub max_array_partition: u64,
}

struct B<'a> {
    k: &'a Kernel,
    a: &'a Analysis,
    dev: &'a Device,
    p: Pool,
}

/// Path-compressed union-find over sibling indices (the `C` operator's
/// dependence components) — identical to the one `eval` runs per call.
fn uf_find(c: &mut [usize], i: usize) -> usize {
    if c[i] != i {
        let r = uf_find(c, c[i]);
        c[i] = r;
    }
    c[i]
}

/// Canonical component root per index, unioning `(i, j)` pairs in the
/// exact `i < j` order `eval`'s inline copies use (the roots — and hence
/// the BTreeMap grouping/iteration order downstream — must match the
/// reference recursion for bit-parity).
fn dep_components(n: usize, mut dep: impl FnMut(usize, usize) -> bool) -> Vec<usize> {
    let mut comp: Vec<usize> = (0..n).collect();
    for i in 0..n {
        for j in i + 1..n {
            if dep(i, j) {
                let (ri, rj) = (uf_find(&mut comp, i), uf_find(&mut comp, j));
                if ri != rj {
                    comp[ri] = rj;
                }
            }
        }
    }
    (0..n).map(|i| uf_find(&mut comp, i)).collect()
}

fn collect_stmts(n: &Node) -> Vec<StmtId> {
    match n {
        Node::Stmt(s) => vec![s.id],
        Node::Loop(l) => l.body.iter().flat_map(collect_stmts).collect(),
    }
}

impl BoundModel {
    /// Build the model once for `(kernel, analysis, device)`.
    pub fn build(k: &Kernel, a: &Analysis, dev: &Device) -> BoundModel {
        let mut b = B {
            k,
            a,
            dev,
            p: Pool::new(),
        };

        // --- computation latency -----------------------------------------
        let roots: Vec<&Node> = k.roots.iter().collect();
        let lat_roots = b.compose(&roots);
        let work_floor = b.work_floor();
        let comp = {
            let wf = b.p.cf(work_floor);
            b.p.max(lat_roots, wf)
        };

        // --- communication latency (constant) ----------------------------
        let mut in_max = 0f64;
        let mut out_max = 0f64;
        for arr in &k.arrays {
            let cyc = dev.transfer_cycles(arr.footprint_bytes(k.dtype));
            if arr.dir.is_live_in() {
                in_max = in_max.max(cyc);
            }
            if arr.dir.is_live_out() {
                out_max = out_max.max(cyc);
            }
        }
        let comm = b.p.cf(in_max + out_max);
        let total = b.p.add(comp, comm);

        // --- resources ----------------------------------------------------
        let dsp = b.dsp_usage();
        let onchip = b.onchip_usage();
        let partitions: Vec<(String, ExprId)> = k
            .arrays
            .iter()
            .map(|arr| (arr.name.clone(), b.partitioning_expr(arr.id)))
            .collect();
        let max_part = {
            let mut m = b.p.cf(1.0);
            for &(_, e) in &partitions {
                m = b.p.max(m, e);
            }
            m
        };

        // --- domains (Eq 1/2/8 hull) ---------------------------------------
        let domains: Vec<VarDomain> = (0..k.n_loops())
            .map(|i| {
                let tc = &a.tcs[i];
                let info = &a.deps.per_loop[i];
                let unrollable = tc.is_constant() && tc.max > 0;
                let dist_cap = match info.min_distance {
                    Some(d) if d > 1 => d,
                    Some(_) if info.serializing && !info.reduction => 1,
                    _ => u64::MAX,
                };
                VarDomain {
                    uf_hi: if unrollable { tc.max.min(dist_cap) } else { 1 },
                    tile_hi: if unrollable { tc.max } else { 1 },
                    indexes_array: loop_indexes_array(k, LoopId(i as u32)),
                }
            })
            .collect();

        // --- constraint set, in legacy `check` report order ----------------
        let mut constraints = Vec::new();
        for i in 0..k.n_loops() {
            let tc = &a.tcs[i];
            constraints.push(Constraint::Divides {
                l: i as u32,
                tc_max: tc.max,
                tc_constant: tc.is_constant(),
            });
            if let Some(d) = a.deps.per_loop[i].min_distance {
                if d > 1 {
                    constraints.push(Constraint::Distance {
                        l: i as u32,
                        dist: d,
                    });
                }
            }
        }
        for (idx, (name, expr)) in partitions.iter().enumerate() {
            constraints.push(Constraint::Partitioning {
                array: idx,
                name: name.clone(),
                expr: *expr,
            });
        }
        constraints.push(Constraint::Dsp {
            expr: dsp,
            budget: dev.dsp_total,
        });
        constraints.push(Constraint::OnChip {
            expr: onchip,
            budget: dev.onchip_bytes,
        });

        b.p.seal(); // construction done; consumers only walk the tape
        BoundModel {
            kernel: k.name.clone(),
            n_loops: k.n_loops(),
            pool: b.p,
            comp,
            comm,
            total,
            dsp,
            onchip,
            max_part,
            partitions,
            constraints,
            domains,
            dsp_total: dev.dsp_total,
            onchip_bytes: dev.onchip_bytes,
            max_array_partition: dev.max_array_partition,
        }
    }

    /// Flatten the model into the allocation-free batch evaluator.
    pub fn compile(&self) -> CompiledModel {
        CompiledModel::from_model(self)
    }

    /// The per-loop interval boxes a partial configuration induces:
    /// assigned pragmas collapse to points, free ones take their Eq 1/2/8
    /// hull (with `UF` additionally capped by `partial.uf_cap` on loops
    /// that index an array).
    pub fn boxes(&self, partial: &PartialDesign) -> Vec<VarBox> {
        assert_eq!(partial.n_loops(), self.n_loops, "partial/kernel mismatch");
        (0..self.n_loops)
            .map(|i| {
                let dom = &self.domains[i];
                let uf = match partial.uf[i] {
                    Some(v) => Interval::point(v as f64),
                    None => {
                        let cap = if dom.indexes_array {
                            partial.uf_cap
                        } else {
                            u64::MAX
                        };
                        Interval::new(1.0, dom.uf_hi.min(cap).max(1) as f64)
                    }
                };
                let tile = match partial.tile[i] {
                    Some(v) => Interval::point(v as f64),
                    None => Interval::new(1.0, dom.tile_hi.max(1) as f64),
                };
                let pip = match partial.pipeline[i] {
                    Some(b) => Interval::point(b as u8 as f64),
                    None => Interval::new(0.0, 1.0),
                };
                VarBox { uf, tile, pip }
            })
            .collect()
    }

    /// Interval of the latency objective over every completion of
    /// `partial` (inclusion-sound: the exact model value of any such
    /// completion lies inside).
    pub fn objective_interval(&self, partial: &PartialDesign) -> Interval {
        let boxes = self.boxes(partial);
        let mut out = Vec::new();
        super::expr::eval_interval(self.pool.nodes(), &boxes, &mut out);
        out[self.total.0 as usize]
    }

    /// Achievable-latency lower bound of a (possibly partial) pragma
    /// configuration — the paper's DSE-pruning primitive: no completion of
    /// `partial` can beat this many cycles.
    pub fn lower_bound(&self, partial: &PartialDesign) -> f64 {
        self.objective_interval(partial).lo
    }

    /// [`lower_bound`](Self::lower_bound) over many partials in one laned
    /// interval sweep: [`super::LANE_WIDTH`] partials share each tape
    /// pass. Per-element results are bit-identical to scalar
    /// `lower_bound` calls (the lanes run the scalar rules; remainder
    /// lanes replicate the last partial and are discarded), so callers —
    /// the solver's bound-ascending dispatch and the DSE ladder's rung
    /// pruning — keep their exact pruning decisions while paying one tape
    /// walk per eight bounds.
    pub fn lower_bound_batch(&self, partials: &[PartialDesign]) -> Vec<f64> {
        use super::expr::{eval_interval_lanes, LANE_WIDTH};
        let mut out = Vec::with_capacity(partials.len());
        let mut iv = Vec::new();
        let mut base = 0;
        while base < partials.len() {
            let live = LANE_WIDTH.min(partials.len() - base);
            let boxes: Vec<Vec<VarBox>> = (0..LANE_WIDTH)
                .map(|j| self.boxes(&partials[base + j.min(live - 1)]))
                .collect();
            let refs: [&[VarBox]; LANE_WIDTH] = std::array::from_fn(|j| boxes[j].as_slice());
            eval_interval_lanes(self.pool.nodes(), &refs, &mut iv);
            for l in 0..live {
                out.push(iv[self.total.0 as usize * LANE_WIDTH + l].lo);
            }
            base += live;
        }
        out
    }
}

fn loop_indexes_array(k: &Kernel, l: LoopId) -> bool {
    for s in k.stmts() {
        for (acc, _) in k.stmt_accesses(s.id) {
            for idx in &acc.indices {
                if idx.loops().any(|il| il == l) {
                    return true;
                }
            }
        }
    }
    false
}

impl<'a> B<'a> {
    /// Theorem 4.4 work floor — design-independent, computed exactly as
    /// `eval` computes it.
    fn work_floor(&self) -> f64 {
        let mut work_floor = 0f64;
        for op in crate::ir::OpKind::ALL {
            let c = self.dev.op_costs(self.k.dtype, op);
            if c.dsp == 0 {
                continue;
            }
            let total_ops: f64 = self
                .k
                .stmts()
                .map(|s| s.op_count(op) as f64 * self.a.stmt_iters[s.id.0 as usize])
                .sum();
            work_floor = work_floor
                .max(total_ops * c.latency as f64 * c.dsp as f64 / self.dev.dsp_total as f64);
        }
        work_floor
    }

    /// The `C` operator: dependent sibling components sum, independent
    /// components overlap (max).
    fn compose(&mut self, nodes: &[&Node]) -> ExprId {
        if nodes.is_empty() {
            return self.p.cf(0.0);
        }
        let lats: Vec<ExprId> = nodes.iter().map(|n| self.lat_node(n)).collect();
        let stmt_sets: Vec<Vec<StmtId>> = nodes.iter().map(|n| collect_stmts(n)).collect();
        let n = nodes.len();
        let roots = dep_components(n, |i, j| {
            stmt_sets[i].iter().any(|&s1| {
                stmt_sets[j]
                    .iter()
                    .any(|&s2| self.a.deps.stmts_dependent(s1, s2))
            })
        });
        self.sum_per_component_then_max(&roots, &lats)
    }

    /// Shared tail of the `C`/`IL` operators: per-component `+` fold in
    /// index order (seeded at 0.0), then a `max` fold over components in
    /// root-key order — `eval`'s BTreeMap accumulation, symbolically.
    fn sum_per_component_then_max(&mut self, roots: &[usize], lats: &[ExprId]) -> ExprId {
        let mut sums: std::collections::BTreeMap<usize, ExprId> = Default::default();
        for (i, &r) in roots.iter().enumerate() {
            let zero = self.p.cf(0.0);
            let e = *sums.entry(r).or_insert(zero);
            let e2 = self.p.add(e, lats[i]);
            sums.insert(r, e2);
        }
        let mut m = self.p.cf(0.0);
        for (_, e) in sums {
            m = self.p.max(m, e);
        }
        m
    }

    /// Latency of one node above any pipeline: the pragma-dependent branch
    /// of `eval::lat_node` becomes a `select` on `pip_l`.
    fn lat_node(&mut self, n: &Node) -> ExprId {
        match n {
            Node::Stmt(s) => {
                let c = self.stmt_chain_latency(s.id);
                self.p.cf(c)
            }
            Node::Loop(l) => {
                let info = self.a.deps.loop_info(l.id).clone();
                let tc = self.a.tc(l.id).avg.max(1.0);
                let innermost = self.k.loop_meta(l.id).innermost;
                let body: Vec<&Node> = l.body.iter().collect();
                let pipe = self.pipe_lat(l.id, &body);
                if innermost {
                    return pipe;
                }
                let other = if info.reduction || info.serializing {
                    let inner = self.compose(&body);
                    let tcc = self.p.cf(tc);
                    self.p.mul(tcc, inner)
                } else {
                    let inner = self.compose(&body);
                    let uf = self.p.uf(l.id.0);
                    let uf1 = self.p.max_c(uf, 1.0);
                    let tcc = self.p.cf(tc);
                    let per = self.p.div(tcc, uf1);
                    let per1 = self.p.max_c(per, 1.0);
                    self.p.mul(per1, inner)
                };
                let pip = self.p.pip(l.id.0);
                self.p.select(pip, pipe, other)
            }
        }
    }

    /// `IL + II × (TC/UF − 1)` (Theorems 4.8/4.9), with the serializing
    /// RecMII adjustment `II ≥ ceil(IL / d)`.
    fn pipe_lat(&mut self, lp: LoopId, body: &[&Node]) -> ExprId {
        let tc = self.a.tc(lp).avg.max(1.0);
        let uf = {
            let u = self.p.uf(lp.0);
            let u1 = self.p.max_c(u, 1.0);
            self.p.min_c(u1, tc)
        };
        let il = self.unrolled_body_latency(body);
        let ii0 = self.pipeline_ii(lp);
        let info = self.a.deps.loop_info(lp).clone();
        let ii = if info.serializing {
            let d = info.min_distance.unwrap_or(1).max(1) as f64;
            let dc = self.p.cf(d);
            let q = self.p.div(il, dc);
            let qc = self.p.ceil(q);
            let i0 = self.p.cf(ii0);
            self.p.max(i0, qc)
        } else {
            self.p.cf(ii0)
        };
        let tcc = self.p.cf(tc);
        let ratio = self.p.div(tcc, uf);
        let one = self.p.cf(1.0);
        let ramp0 = self.p.sub(ratio, one);
        let ramp = self.p.max_c(ramp0, 0.0);
        let rampii = self.p.mul(ii, ramp);
        self.p.add(il, rampii)
    }

    /// Structural (design-independent) minimal II of a pipelined loop —
    /// mirrors `eval::pipeline_ii`.
    fn pipeline_ii(&self, lp: LoopId) -> f64 {
        let info = self.a.deps.loop_info(lp);
        let mut ii = 1.0f64;
        if info.reduction {
            if let Some(op) = info.reduction_op {
                ii = ii.max(self.dev.op_costs(self.k.dtype, op).latency as f64);
            }
        }
        if info.serializing {
            let d = info.min_distance.unwrap_or(1).max(1) as f64;
            let max_chain = self
                .k
                .loop_meta(lp)
                .stmts
                .iter()
                .map(|&s| self.stmt_chain_latency(s))
                .fold(1.0f64, f64::max);
            ii = ii.max((max_chain / d).ceil());
        }
        ii
    }

    /// The `SL`/`IL` term: statements under the pipeline with their
    /// tree-reduction and serial factors (now expressions in the inner
    /// UFs), composed by dependence.
    fn unrolled_body_latency(&mut self, body: &[&Node]) -> ExprId {
        let mut items: Vec<(StmtId, ExprId, ExprId)> = Vec::new();
        let one = self.p.cf(1.0);
        // (node, tree-factor expr, serial-factor expr) worklist, mirroring
        // eval's recursive walk order (depth-first, body order)
        fn walk(
            b: &mut B<'_>,
            n: &Node,
            tf: ExprId,
            sf: ExprId,
            items: &mut Vec<(StmtId, ExprId, ExprId)>,
        ) {
            match n {
                Node::Stmt(s) => items.push((s.id, tf, sf)),
                Node::Loop(l) => {
                    let info = b.a.deps.loop_info(l.id).clone();
                    let tc = b.a.tc(l.id).avg.max(1.0);
                    let ufc = {
                        let u = b.p.uf(l.id.0);
                        let u1 = b.p.max_c(u, 1.0);
                        b.p.min_c(u1, tc)
                    };
                    let (tfc, sfc) = if info.reduction {
                        // Theorem 4.7: (TC/UF) tree passes of depth log2(UF)
                        let tcc = b.p.cf(tc);
                        let ratio = b.p.div(tcc, ufc);
                        let depth = b.p.treelog(ufc);
                        (b.p.mul(ratio, depth), b.p.cf(1.0))
                    } else if info.serializing {
                        (b.p.cf(1.0), b.p.cf(tc))
                    } else {
                        let tcc = b.p.cf(tc);
                        let ratio = b.p.div(tcc, ufc);
                        (b.p.cf(1.0), b.p.max_c(ratio, 1.0))
                    };
                    let tf2 = b.p.mul(tf, tfc);
                    let sf2 = b.p.mul(sf, sfc);
                    for c in &l.body {
                        walk(b, c, tf2, sf2, items);
                    }
                }
            }
        }
        for n in body {
            walk(self, n, one, one, &mut items);
        }
        if items.is_empty() {
            return self.p.cf(1.0);
        }

        let lats: Vec<ExprId> = items
            .iter()
            .map(|&(sid, tf, sf)| {
                let ul = self.stmt_unrolled_latency(sid, tf);
                self.p.mul(ul, sf)
            })
            .collect();

        let n = items.len();
        let roots = dep_components(n, |i, j| {
            self.a.deps.stmts_dependent(items[i].0, items[j].0)
        });
        let il = self.sum_per_component_then_max(&roots, &lats);
        self.p.max_c(il, 1.0)
    }

    /// One statement inside the unrolled pipeline body: the reduction op
    /// of the chain is charged `tf` times when `tf > 1` (tree levels ×
    /// sequential passes); chains with no reduction op scale wholesale.
    fn stmt_unrolled_latency(&mut self, sid: StmtId, tf: ExprId) -> ExprId {
        let s = self.k.stmt(sid);
        if s.chain.is_empty() {
            return self.p.cf(1.0);
        }
        let red_op = self.a.deps.reductions_of(sid).map(|(_, op)| op).next();
        let costs: Vec<f64> = s
            .chain
            .iter()
            .map(|&op| self.dev.op_costs(self.k.dtype, op).latency as f64)
            .collect();
        let red_pos = red_op.and_then(|ro| s.chain.iter().position(|&op| op == ro));

        // the tf ≤ 1 value: the plain chain sum, folded exactly as eval's
        // accumulation loop folds it
        let mut base = 0f64;
        for &c in &costs {
            base += c;
        }
        let base_e = self.p.cf(base);

        let one = self.p.cf(1.0);
        let scaled = self.p.gt(tf, one);
        let lat = match red_pos {
            Some(pos) => {
                // charge the first reduction-op occurrence `tf` times,
                // keeping eval's left-to-right accumulation order
                let mut acc = self.p.cf(0.0);
                for (i, &c) in costs.iter().enumerate() {
                    let cc = self.p.cf(c);
                    let term = if i == pos { self.p.mul(cc, tf) } else { cc };
                    acc = self.p.add(acc, term);
                }
                self.p.select(scaled, acc, base_e)
            }
            None => {
                let all = self.p.mul(base_e, tf);
                self.p.select(scaled, all, base_e)
            }
        };
        self.p.max_c(lat, 1.0)
    }

    /// Op-chain latency constant of one statement iteration (≥ 1 cycle).
    fn stmt_chain_latency(&self, sid: StmtId) -> f64 {
        let s = self.k.stmt(sid);
        if s.chain.is_empty() {
            return 1.0;
        }
        s.chain
            .iter()
            .map(|&op| self.dev.op_costs(self.k.dtype, op).latency as f64)
            .sum::<f64>()
            .max(1.0)
    }

    /// Theorem 4.12 / Eq 11: per nest, independent components need
    /// concurrent units (sum) while sequential ones share (max);
    /// pipeline sharing divides by the II of the governing pipeline —
    /// a `select` chain over the ancestry's `pip` unknowns.
    fn dsp_usage(&mut self) -> ExprId {
        let k = self.k;
        let mut worst = self.p.cf(0.0);
        for root in k.nest_roots() {
            let stmts = k.loop_meta(root).stmts.clone();
            if stmts.is_empty() {
                continue;
            }
            let n = stmts.len();
            let roots =
                dep_components(n, |i, j| self.a.deps.stmts_dependent(stmts[i], stmts[j]));
            let mut per_comp: std::collections::BTreeMap<usize, ExprId> = Default::default();
            for (idx, &sid) in stmts.iter().enumerate() {
                let nest = k.stmt_meta(sid).nest.clone();
                let mut mcu = self.p.cf(1.0);
                for &l in &nest {
                    let tc = self.a.tc(l).avg.max(1.0);
                    let u = self.p.uf(l.0);
                    let u1 = self.p.max_c(u, 1.0);
                    let uc = self.p.min_c(u1, tc);
                    mcu = self.p.mul(mcu, uc);
                }
                let dsp_one: f64 = k
                    .stmt(sid)
                    .ops
                    .iter()
                    .map(|&(op, c)| c as f64 * self.dev.op_costs(k.dtype, op).dsp as f64)
                    .sum();
                // nearest enclosing pipelined loop's (structural) II, as a
                // select chain from the innermost loop outward
                let innermost = *nest.last().unwrap();
                let ii_sel = self.pipeline_above_ii(innermost);
                let d1 = self.p.cf(dsp_one);
                let num = self.p.mul(d1, mcu);
                let ii1 = self.p.max_c(ii_sel, 1.0);
                let need = self.p.div(num, ii1);
                let r = roots[idx];
                let zero = self.p.cf(0.0);
                let e = *per_comp.entry(r).or_insert(zero);
                let e2 = self.p.max(e, need);
                per_comp.insert(r, e2);
            }
            let mut nest_dsp = self.p.cf(0.0);
            for (_, e) in per_comp {
                nest_dsp = self.p.add(nest_dsp, e);
            }
            worst = self.p.max(worst, nest_dsp);
        }
        worst
    }

    /// `pipeline_above(l).map(pipeline_ii).unwrap_or(1.0)` as an
    /// expression: walk the ancestry, selecting the first loop whose
    /// `pip` unknown is set.
    fn pipeline_above_ii(&mut self, l: LoopId) -> ExprId {
        let path = self.k.loop_path(l); // root .. l
        let mut sel = self.p.cf(1.0);
        // fold root-first so the deepest loop's select ends up outermost:
        // the *innermost* pipelined ancestor must win, matching
        // `Design::pipeline_above`'s inside-out walk
        for &anc in &path {
            let ii = self.pipeline_ii(anc);
            let iic = self.p.cf(ii);
            let pip = self.p.pip(anc.0);
            sel = self.p.select(pip, iic, sel);
        }
        sel
    }

    /// Eq 12: cached on-chip bytes, with `tile` shrinking the cached
    /// extent of the dimensions its loop indexes.
    fn onchip_usage(&mut self) -> ExprId {
        let k = self.k;
        let mut total = self.p.cf(0.0);
        for arr in &k.arrays {
            let mut per_dim: Vec<ExprId> =
                arr.dims.iter().map(|&d| self.p.cf(d as f64)).collect();
            for s in k.stmts() {
                for (acc, _) in k.stmt_accesses(s.id) {
                    if acc.array != arr.id {
                        continue;
                    }
                    for (d, idx) in acc.indices.iter().enumerate() {
                        for l in idx.loops() {
                            let tc = self.a.tc(l).max.max(1);
                            let tile = self.p.tile(l.0);
                            let one = self.p.cf(1.0);
                            let tcc = self.p.cf(tc as f64);
                            let g = self.p.gt(tile, one);
                            let lt = self.p.lt(tile, tcc);
                            let cond = self.p.and(g, lt);
                            let dim = self.p.cf(arr.dims[d] as f64);
                            let scale = self.p.div(tile, tcc);
                            let cand = self.p.mul(dim, scale);
                            let shrunk = self.p.min(per_dim[d], cand);
                            per_dim[d] = self.p.select(cond, shrunk, per_dim[d]);
                        }
                    }
                }
            }
            let mut elems = self.p.cf(1.0);
            for &e in &per_dim {
                elems = self.p.mul(elems, e);
            }
            let bpe = self.p.cf(k.dtype.bits() as f64 / 8.0);
            let bytes = self.p.mul(elems, bpe);
            let capped = self.p.min_c(bytes, self.dev.working_tile_bytes() as f64);
            total = self.p.add(total, capped);
        }
        total
    }

    /// Eq 13: per-array cross-dimension partitioning — the product over
    /// dimensions of the max UF of loops indexing each dimension.
    fn partitioning_expr(&mut self, a: crate::ir::ArrayId) -> ExprId {
        let k = self.k;
        let mut per_dim: Vec<ExprId> = vec![self.p.cf(1.0); k.array(a).dims.len()];
        for s in k.stmts() {
            for (acc, _) in k.stmt_accesses(s.id) {
                if acc.array != a {
                    continue;
                }
                for (d, idx) in acc.indices.iter().enumerate() {
                    for l in idx.loops() {
                        let u = self.p.uf(l.0);
                        per_dim[d] = self.p.max(per_dim[d], u);
                    }
                }
            }
        }
        let mut prod = self.p.cf(1.0);
        for &e in &per_dim {
            prod = self.p.mul(prod, e);
        }
        prod
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks;
    use crate::ir::DType;

    #[test]
    fn builds_for_every_benchmark() {
        for name in benchmarks::ALL {
            let size = if name == "cnn" {
                benchmarks::Size::Medium
            } else {
                benchmarks::Size::Small
            };
            let k = benchmarks::build(name, size, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let bm = BoundModel::build(&k, &a, &Device::u200());
            assert!(!bm.pool.is_empty(), "{name}: empty pool");
            assert_eq!(bm.n_loops, k.n_loops());
            assert_eq!(bm.partitions.len(), k.arrays.len());
            // at least divisibility per loop + dsp + onchip
            assert!(bm.constraints.len() >= k.n_loops() + 2, "{name}");
        }
    }

    #[test]
    fn batched_lower_bounds_bit_match_scalar() {
        // mixed caps and partial assignments across an odd count (11) so
        // both full chunks and the replicated remainder lanes are hit
        let k = benchmarks::build("gemm", benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let bm = BoundModel::build(&k, &a, &Device::u200());
        let mut partials = Vec::new();
        for cap in [u64::MAX, 1024, 256, 64, 16, 4, 1] {
            partials.push(PartialDesign::free(k.n_loops()).with_uf_cap(cap));
        }
        for uf in [1u64, 2, 8, 32] {
            let mut p = PartialDesign::free(k.n_loops());
            p.assign_uf(LoopId(0), uf);
            partials.push(p);
        }
        assert_eq!(partials.len(), 11);
        let batch = bm.lower_bound_batch(&partials);
        assert_eq!(batch.len(), partials.len());
        for (i, p) in partials.iter().enumerate() {
            assert_eq!(
                bm.lower_bound(p).to_bits(),
                batch[i].to_bits(),
                "partial {i}"
            );
        }
        assert!(bm.lower_bound_batch(&[]).is_empty());
    }

    #[test]
    fn domains_respect_triangular_and_distance_caps() {
        let k = benchmarks::build("lu", benchmarks::Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let bm = BoundModel::build(&k, &a, &Device::u200());
        // triangular loops (non-constant TC) are not unrollable
        for (i, tc) in a.tcs.iter().enumerate() {
            if !tc.is_constant() {
                assert_eq!(bm.domains[i].uf_hi, 1, "loop {i}");
            }
        }
    }
}
