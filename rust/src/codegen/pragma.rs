//! The pragma annotation layer: per-loop pragma text in two dialects.
//!
//! * [`Dialect::Merlin`] — the paper's source-to-source flow:
//!   `#pragma ACCEL parallel factor=UF` / `#pragma ACCEL tile factor=T`
//!   / `#pragma ACCEL pipeline` placed **before** the loop header, plus
//!   `#pragma ACCEL cache variable=A` at the outermost position of each
//!   nest (the placement simulated `merlin::` applies automatically —
//!   Section 2.1).
//! * [`Dialect::Vitis`] — raw Vitis HLS: `#pragma HLS unroll factor=UF`
//!   / `#pragma HLS pipeline II=1` placed just **inside** the loop
//!   body, plus `#pragma HLS array_partition variable=A cyclic
//!   factor=F dim=D` at function scope (the partitioning Merlin would
//!   derive — Section 6's cross-dimension product, per dimension).
//!
//! When the emission is *realized* (`EmitConfig::realized`), the
//! annotation is computed from the design Merlin actually implements,
//! and every pragma the simulator refused is kept visible as a
//! `// not applied:` comment in place of the pragma line — the paper's
//! §7.5 observation ("about half of the designs have at least one
//! pragma not applied") made inspectable in the artifact itself.

use crate::ir::{ArrayId, Kernel, LoopId};
use crate::pragma::Design;

/// Output pragma dialect of the C emitter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dialect {
    /// AMD/Xilinx Merlin `#pragma ACCEL` annotations (the paper's flow).
    Merlin,
    /// Raw Vitis HLS `#pragma HLS` annotations (no Merlin in the loop).
    Vitis,
}

impl Dialect {
    /// Stable lowercase name (CLI `--dialect` value, file-name infix).
    pub fn name(self) -> &'static str {
        match self {
            Dialect::Merlin => "merlin",
            Dialect::Vitis => "vitis",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn parse(s: &str) -> Option<Dialect> {
        match s.to_ascii_lowercase().as_str() {
            "merlin" | "accel" => Some(Dialect::Merlin),
            "vitis" | "hls" => Some(Dialect::Vitis),
            _ => None,
        }
    }
}

/// Pragma lines computed per loop and per function, ready for the C
/// emitter to indent and splice.
pub(crate) struct Annotations {
    /// Function-scope lines, emitted right after the opening brace
    /// (Vitis `array_partition` directives).
    pub fn_top: Vec<String>,
    /// Lines placed immediately **before** loop `i`'s `for` header
    /// (Merlin placement).
    pub before: Vec<Vec<String>>,
    /// Lines placed immediately **inside** loop `i`'s body (Vitis
    /// placement).
    pub inside: Vec<Vec<String>>,
}

/// Compute the annotation for `effective` (what the pragmas say), with
/// `requested` kept alongside so refused pragmas surface as comments.
/// In requested mode the two are the same design and no refusal
/// comments are generated.
pub(crate) fn annotate(
    k: &Kernel,
    requested: &Design,
    effective: &Design,
    dialect: Dialect,
) -> Annotations {
    let n = k.n_loops();
    let mut ann = Annotations {
        fn_top: Vec::new(),
        before: vec![Vec::new(); n],
        inside: vec![Vec::new(); n],
    };

    if dialect == Dialect::Vitis {
        // function-scope partitioning: per-dimension max-UF factors of
        // the effective design (Design::partitioning_dims), cyclic —
        // Merlin's derivation made explicit for the raw-Vitis flow
        for arr in &k.arrays {
            for (dim, f) in effective.partitioning_dims(k, arr.id).iter().enumerate() {
                if *f > 1 {
                    ann.fn_top.push(format!(
                        "#pragma HLS array_partition variable={} cyclic factor={} dim={}",
                        arr.name,
                        f,
                        dim + 1
                    ));
                }
            }
        }
    }

    if dialect == Dialect::Merlin {
        // cache pragmas at the outermost position of each nest, one per
        // array the nest touches (simulated Merlin's automatic placement)
        for root in k.nest_roots() {
            let lines = &mut ann.before[root.0 as usize];
            for a in nest_arrays(k, root) {
                lines.push(format!("#pragma ACCEL cache variable={}", k.array(a).name));
            }
        }
    }

    for i in 0..n {
        let l = LoopId(i as u32);
        let req = requested.get(l);
        let eff = effective.get(l);
        let target = match dialect {
            Dialect::Merlin => &mut ann.before[i],
            Dialect::Vitis => &mut ann.inside[i],
        };
        match dialect {
            Dialect::Merlin => {
                if eff.pipeline {
                    target.push("#pragma ACCEL pipeline".into());
                }
                if eff.tile > 1 {
                    target.push(format!("#pragma ACCEL tile factor={}", eff.tile));
                }
                if eff.uf > 1 {
                    target.push(format!("#pragma ACCEL parallel factor={}", eff.uf));
                }
            }
            Dialect::Vitis => {
                if eff.pipeline {
                    target.push("#pragma HLS pipeline II=1".into());
                }
                if eff.uf > 1 {
                    target.push(format!("#pragma HLS unroll factor={}", eff.uf));
                }
                if eff.tile > 1 {
                    // no direct Vitis pragma: Merlin realizes `tile` by
                    // strip-mining the loop before HLS sees it
                    target.push(format!(
                        "// tile factor={} (Merlin strip-mines; no direct Vitis pragma)",
                        eff.tile
                    ));
                }
            }
        }
        // refusal comments: every knob where the realized design lost
        // the requested pragma stays visible at the loop it annotated
        if req.pipeline && !eff.pipeline {
            target.push("// not applied: pipeline (refused by Merlin)".into());
        }
        if req.tile > 1 && eff.tile != req.tile {
            target.push(format!(
                "// not applied: tile factor={} (refused by Merlin)",
                req.tile
            ));
        }
        if req.uf > 1 && eff.uf != req.uf {
            target.push(format!(
                "// not applied: parallel factor={} (refused by Merlin)",
                req.uf
            ));
        }
    }
    ann
}

/// Arrays accessed by statements under nest root `root`, by id order.
fn nest_arrays(k: &Kernel, root: LoopId) -> Vec<ArrayId> {
    let mut ids: Vec<ArrayId> = Vec::new();
    for &s in &k.loop_meta(root).stmts {
        for (acc, _) in k.stmt_accesses(s) {
            if !ids.contains(&acc.array) {
                ids.push(acc.array);
            }
        }
    }
    ids.sort();
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::DType;
    use crate::pragma::LoopPragma;

    #[test]
    fn dialect_parse_roundtrips() {
        for d in [Dialect::Merlin, Dialect::Vitis] {
            assert_eq!(Dialect::parse(d.name()), Some(d));
        }
        assert_eq!(Dialect::parse("hls"), Some(Dialect::Vitis));
        assert_eq!(Dialect::parse("nope"), None);
    }

    #[test]
    fn merlin_annotation_places_loop_pragmas_before() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(2)).pipeline = true; // k
        d.get_mut(LoopId(2)).uf = 4;
        let ann = annotate(&k, &d, &d, Dialect::Merlin);
        assert!(ann.fn_top.is_empty());
        assert!(ann.before[2].contains(&"#pragma ACCEL pipeline".to_string()));
        assert!(ann.before[2].contains(&"#pragma ACCEL parallel factor=4".to_string()));
        assert!(ann.inside.iter().all(|v| v.is_empty()));
        // cache pragmas sit at the (only) nest root
        assert!(ann.before[0].iter().any(|l| l.starts_with("#pragma ACCEL cache")));
    }

    #[test]
    fn vitis_annotation_places_partitioning_at_fn_top() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(2)).uf = 8; // k indexes A dim 1, B dim 0
        let ann = annotate(&k, &d, &d, Dialect::Vitis);
        assert!(ann
            .fn_top
            .iter()
            .any(|l| l.contains("variable=A") && l.contains("factor=8") && l.contains("dim=2")));
        assert!(ann.inside[2].contains(&"#pragma HLS unroll factor=8".to_string()));
        assert!(ann.before.iter().all(|v| v.is_empty()));
    }

    #[test]
    fn refused_pragma_becomes_comment() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let req = Design::empty(&k).with(
            LoopId(0),
            LoopPragma {
                uf: 8,
                tile: 1,
                pipeline: false,
            },
        );
        let eff = Design::empty(&k); // Merlin reset the parallel pragma
        let ann = annotate(&k, &req, &eff, Dialect::Merlin);
        let pragma_hit = ann.before[0]
            .iter()
            .any(|l| l.contains("parallel factor=8") && l.starts_with('#'));
        assert!(!pragma_hit);
        assert!(ann.before[0]
            .iter()
            .any(|l| l == "// not applied: parallel factor=8 (refused by Merlin)"));
    }
}
