//! Pragma-annotated HLS C emission — the system's exit path.
//!
//! The paper's deliverable is *inserted pragmas in source code*: its
//! end-to-end flow takes a loop-based kernel and produces a
//! Merlin/Vitis-ready annotated C program (Sections 1 and 7). Upstream
//! of this module the repo already covers text in (`frontend`, the
//! `.knl` DSL) through solving (`nlp`, `dse`, `engine`); `codegen`
//! closes the loop from a solved [`crate::pragma::Design`] back out to
//! compilable C:
//!
//! * [`c`] — the IR → C lowering (declarations, array parameters, loop
//!   headers, representative statement bodies);
//! * [`pragma`] — the annotation layer with two dialects:
//!   [`Dialect::Merlin`] (`#pragma ACCEL parallel/pipeline/tile/cache`)
//!   and [`Dialect::Vitis`] (`#pragma HLS unroll/pipeline/
//!   array_partition`);
//! * [`lint`](mod@lint) — a structural re-parse (balanced delimiters,
//!   one loop header per IR loop, pragma attachment) standing in for a
//!   C compiler in the offline environment;
//! * **realized mode** (`EmitConfig::realized`) — runs the simulated
//!   Merlin compiler ([`crate::merlin::apply`]) and emits what it
//!   *actually accepted*, keeping every refused pragma visible as a
//!   `// not applied:` comment (the Section 7.5 discrepancies, made
//!   inspectable).
//!
//! Entry points: [`emit`] here, [`crate::engine::Explorer::emit`] /
//! [`crate::engine::Explorer::emit_best`] for exploration outcomes, the
//! CLI `emit` command, and `campaign --emit-dir` (one annotated file
//! per campaign row × engine). Architecture notes: DESIGN.md §10.
//!
//! ```no_run
//! use nlp_dse::benchmarks::Size;
//! use nlp_dse::codegen::EmitConfig;
//! use nlp_dse::engine::Explorer;
//!
//! # fn main() -> anyhow::Result<()> {
//! let explorer = Explorer::kernel("gemm", Size::Medium)?;
//! let outcome = explorer.run()?;
//! if let Some(code) = explorer.emit_best(&outcome, &EmitConfig::merlin()) {
//!     std::fs::write("gemm_annotated.c", code)?;
//! }
//! # Ok(())
//! # }
//! ```

pub mod c;
pub mod lint;
pub mod pragma;

pub use lint::{lint, LintReport};
pub use pragma::Dialect;

use crate::hls::Device;
use crate::ir::Kernel;
use crate::merlin::{MerlinOutcome, Reject};
use crate::poly::Analysis;
use crate::pragma::Design;

/// How to render a design as annotated C.
#[derive(Clone, Copy, Debug)]
pub struct EmitConfig {
    /// Pragma dialect of the output.
    pub dialect: Dialect,
    /// Emit what simulated Merlin *realizes* instead of what was
    /// requested: refused pragmas become `// not applied:` comments and
    /// the header reports the realization outcome.
    pub realized: bool,
}

impl Default for EmitConfig {
    fn default() -> Self {
        EmitConfig {
            dialect: Dialect::Merlin,
            realized: false,
        }
    }
}

impl EmitConfig {
    /// Requested-pragma Merlin output (the default).
    pub fn merlin() -> EmitConfig {
        EmitConfig::default()
    }

    /// Requested-pragma raw Vitis output.
    pub fn vitis() -> EmitConfig {
        EmitConfig {
            dialect: Dialect::Vitis,
            realized: false,
        }
    }

    /// Switch this config to realized mode.
    pub fn realized(mut self) -> EmitConfig {
        self.realized = true;
        self
    }
}

/// Lower `design` on `k` to pragma-annotated HLS C text.
///
/// In requested mode the pragmas are emitted exactly as given. In
/// realized mode (`EmitConfig::realized`) the simulated Merlin
/// compiler decides what is actually applied; the emitted pragma set is
/// then exactly the realized design's, and the output differs from the
/// requested-mode emission precisely at the pragmas Merlin refused
/// (plus the outcome header) — the invariant the golden and fuzz suites
/// assert.
pub fn emit(k: &Kernel, a: &Analysis, dev: &Device, design: &Design, cfg: &EmitConfig) -> String {
    let outcome = cfg.realized.then(|| crate::merlin::apply(k, a, dev, design));
    let effective = outcome
        .as_ref()
        .map(|o| o.realized.clone())
        .unwrap_or_else(|| design.clone());
    let ann = pragma::annotate(k, design, &effective, cfg.dialect);
    let header = header_lines(k, design, outcome.as_ref(), cfg);
    c::emit_source(k, &ann, &header)
}

/// The `// …` header block: provenance, the requested design, and (in
/// realized mode) the Merlin outcome summary.
fn header_lines(
    k: &Kernel,
    design: &Design,
    outcome: Option<&MerlinOutcome>,
    cfg: &EmitConfig,
) -> Vec<String> {
    let mut h = vec![
        format!(
            "{} — pragma-annotated HLS C emitted by nlp-dse (dialect: {})",
            k.name,
            cfg.dialect.name()
        ),
        format!(
            "dtype: {}   loops: {}   statements: {}   design: {}",
            k.dtype.name(),
            k.n_loops(),
            k.n_stmts(),
            design.fingerprint()
        ),
    ];
    let Some(o) = outcome else {
        h.push("mode: requested (pragmas emitted exactly as configured)".into());
        return h;
    };
    h.push("mode: realized (what simulated Merlin actually applies — Section 7.5)".into());
    if o.early_reject {
        h.push(
            "merlin: DESIGN EARLY-REJECTED (analysis failed outright; \
             pragmas kept as requested for inspection)"
                .into(),
        );
    } else if o.rejects.is_empty() {
        h.push("merlin: all requested pragmas applied".into());
    } else {
        h.push(format!("merlin: {} pragma(s) not applied:", o.rejects.len()));
        for r in &o.rejects {
            h.push(format!("  - {}", reject_label(k, r)));
        }
    }
    if o.ii_penalty > 1.0 {
        h.push(format!(
            "achieved II multiplier: x{:.1} (imperfect partitioning)",
            o.ii_penalty
        ));
    }
    if o.flattened {
        h.push("vitis auto-applied loop_flatten (the Fig 5 lower-bound exception)".into());
    }
    h.push(format!("realized communication: {:.0} cycles", o.comm_cycles));
    h
}

/// Human-readable refusal description.
fn reject_label(k: &Kernel, r: &Reject) -> String {
    match r {
        Reject::CoarseGrained(l) => format!(
            "loop `{}` (L{}): coarse-grained parallel refused",
            k.loop_name(*l),
            l.0
        ),
        Reject::Partitioning(l) => format!(
            "loop `{}` (L{}): implied array partitioning not realizable",
            k.loop_name(*l),
            l.0
        ),
        Reject::EarlyReject => "whole design refused (early reject)".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::{DType, LoopId};

    fn setup(name: &str) -> (Kernel, Analysis, Device) {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        (k, a, Device::u200())
    }

    /// `#pragma` lines of an emission, trimmed, in order.
    fn pragma_lines(code: &str) -> Vec<String> {
        code.lines()
            .map(str::trim_start)
            .filter(|l| l.starts_with("#pragma"))
            .map(str::to_string)
            .collect()
    }

    #[test]
    fn requested_and_realized_agree_when_everything_applies() {
        let (k, a, dev) = setup("gemm");
        let d = Design::empty(&k);
        let req = emit(&k, &a, &dev, &d, &EmitConfig::merlin());
        let real = emit(&k, &a, &dev, &d, &EmitConfig::merlin().realized());
        assert_eq!(pragma_lines(&req), pragma_lines(&real));
        assert!(real.contains("all requested pragmas applied"), "{real}");
        lint(&k, &req).unwrap();
        lint(&k, &real).unwrap();
    }

    #[test]
    fn realized_differs_exactly_at_refused_pragmas() {
        // find a coarse-grained refusal across the suite (deterministic
        // per kernel — merlin hashes the kernel/loop key)
        let mut exercised = false;
        for name in ["2mm", "3mm", "gemver", "gemm", "doitgen"] {
            let (k, a, dev) = setup(name);
            for i in 0..k.n_loops() {
                let meta = k.loop_meta(LoopId(i as u32));
                if meta.innermost {
                    continue;
                }
                let tc = &a.tcs[i];
                if !tc.is_constant() || tc.max < 2 {
                    continue;
                }
                let uf = *crate::util::divisors(tc.max).get(1).unwrap_or(&1);
                if uf == 1 {
                    continue;
                }
                let mut d = Design::empty(&k);
                d.pragmas[i].uf = uf;
                let o = crate::merlin::apply(&k, &a, &dev, &d);
                if o.early_reject || o.realized == d {
                    continue;
                }
                exercised = true;
                let req = emit(&k, &a, &dev, &d, &EmitConfig::merlin());
                let real = emit(&k, &a, &dev, &d, &EmitConfig::merlin().realized());
                // realized emission's pragma set == requested emission of
                // the realized design; the refused pragma is gone but
                // stays visible as a comment
                let of_realized = emit(&k, &a, &dev, &o.realized, &EmitConfig::merlin());
                assert_eq!(pragma_lines(&real), pragma_lines(&of_realized), "{name}");
                assert_ne!(pragma_lines(&real), pragma_lines(&req), "{name}");
                assert!(real.contains("// not applied: parallel factor="), "{name}:\n{real}");
                lint(&k, &real).unwrap();
            }
        }
        assert!(exercised, "no coarse refusal found in the probe set");
    }

    #[test]
    fn vitis_and_merlin_disagree_only_in_pragma_dialect() {
        let (k, a, dev) = setup("gemm");
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(2)).pipeline = true;
        d.get_mut(LoopId(2)).uf = 4;
        let m = emit(&k, &a, &dev, &d, &EmitConfig::merlin());
        let v = emit(&k, &a, &dev, &d, &EmitConfig::vitis());
        assert!(m.contains("#pragma ACCEL parallel factor=4"), "{m}");
        assert!(v.contains("#pragma HLS unroll factor=4"), "{v}");
        assert!(!v.contains("ACCEL"), "{v}");
        assert!(!m.contains("#pragma HLS"), "{m}");
        // the C skeleton (non-pragma, non-comment lines) is identical
        let skel = |s: &str| {
            s.lines()
                .map(str::trim_start)
                .filter(|l| !l.starts_with("#pragma") && !l.starts_with("//") && !l.is_empty())
                .map(str::to_string)
                .collect::<Vec<_>>()
        };
        assert_eq!(skel(&m), skel(&v));
    }

    #[test]
    fn every_registry_kernel_emits_lintable_c_in_both_dialects() {
        for name in benchmarks::ALL {
            let size = if name == "cnn" { Size::Medium } else { Size::Small };
            let k = benchmarks::build(name, size, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let dev = Device::u200();
            let mut d = Design::empty(&k);
            for i in 0..k.n_loops() {
                if k.loops[i].innermost {
                    d.pragmas[i].pipeline = true;
                }
            }
            for cfg in [
                EmitConfig::merlin(),
                EmitConfig::vitis(),
                EmitConfig::merlin().realized(),
            ] {
                let code = emit(&k, &a, &dev, &d, &cfg);
                lint(&k, &code).unwrap_or_else(|e| {
                    let dialect = cfg.dialect.name();
                    panic!("{name} ({dialect}, realized={}): {e}\n{code}", cfg.realized)
                });
            }
        }
    }
}
