//! IR → C lowering: declarations, array parameters, loop headers and
//! representative statement bodies.
//!
//! The summary IR carries *access lists and op multisets*, not full
//! expression trees (scalar constants like `alpha`/`beta` are folded
//! away by construction — Section 3.1's property-vector abstraction),
//! so statement bodies are **representative**: each emitted statement
//! performs exactly the declared reads/writes and exactly the declared
//! op multiset in chain order, which is what the latency/resource model
//! scores. Reduction statements (a read of the written access) emit the
//! accumulator last — `out[j][h][w] = (in[..] * weight[..]) + out[..];`
//! — so the canonical corpus shapes read naturally. When a hand-written
//! `.knl` statement declares fewer ops than the fold needs to reach
//! every read, the leftover reads are emitted as `(void)` reads rather
//! than silently dropped or padded with invented ops.
//!
//! Lowering map (DESIGN.md §10): arrays with a transfer direction
//! become function parameters (`const` for live-in only), `temp` arrays
//! become `static` function-local declarations, loops become
//! `for (int it = LB; it < UB; it++)` with affine bounds rendered over
//! enclosing iterator names, and statement names survive as `/* S */`
//! comments so emitted text can be traced back to the `.knl` source.

use super::pragma::Annotations;
use crate::ir::{Access, AffineExpr, ArrayDir, DType, Kernel, Node, Stmt};

/// C scalar type of a kernel dtype.
pub(crate) fn c_type(dtype: DType) -> &'static str {
    match dtype {
        DType::F32 => "float",
        DType::F64 => "double",
    }
}

/// C function identifier: `kernel_` + the kernel name with every
/// non-identifier character mapped to `_` (PolyBench names like `2mm`
/// or `floyd-warshall` are not valid C identifiers on their own).
pub(crate) fn c_fn_name(kernel: &str) -> String {
    let mut out = String::from("kernel_");
    for ch in kernel.chars() {
        if ch.is_ascii_alphanumeric() || ch == '_' {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    out
}

/// Render the full C source: header comment, signature, local `temp`
/// declarations, function-scope annotation lines, then the loop nests.
pub(crate) fn emit_source(k: &Kernel, ann: &Annotations, header: &[String]) -> String {
    let ty = c_type(k.dtype);
    let mut out = String::new();
    for line in header {
        out.push_str("// ");
        out.push_str(line);
        out.push('\n');
    }

    // signature: every array that crosses the off-chip boundary is a
    // parameter; pure temps are function-local
    let mut params: Vec<String> = Vec::new();
    for a in &k.arrays {
        if a.dir == ArrayDir::Temp {
            continue;
        }
        let qual = if a.dir == ArrayDir::In { "const " } else { "" };
        let dims: String = a.dims.iter().map(|d| format!("[{d}]")).collect();
        params.push(format!("{qual}{ty} {}{dims}", a.name));
    }
    let params = if params.is_empty() {
        "void".to_string()
    } else {
        params.join(", ")
    };
    out.push_str(&format!("void {}({params}) {{\n", c_fn_name(&k.name)));

    for a in &k.arrays {
        if a.dir == ArrayDir::Temp {
            let dims: String = a.dims.iter().map(|d| format!("[{d}]")).collect();
            out.push_str(&format!("  static {ty} {}{dims};\n", a.name));
        }
    }
    for line in &ann.fn_top {
        out.push_str("  ");
        out.push_str(line);
        out.push('\n');
    }

    for root in &k.roots {
        out.push('\n');
        emit_node(k, ann, root, 1, &mut out);
    }
    out.push_str("}\n");
    out
}

fn indent(levels: usize, out: &mut String) {
    for _ in 0..levels {
        out.push_str("  ");
    }
}

fn emit_node(k: &Kernel, ann: &Annotations, n: &Node, depth: usize, out: &mut String) {
    match n {
        Node::Loop(l) => {
            let idx = l.id.0 as usize;
            for line in &ann.before[idx] {
                indent(depth, out);
                out.push_str(line);
                out.push('\n');
            }
            indent(depth, out);
            out.push_str(&format!(
                "for (int {it} = {lb}; {it} < {ub}; {it}++) {{\n",
                it = l.name,
                lb = affine_c(k, &l.lb),
                ub = affine_c(k, &l.ub)
            ));
            for line in &ann.inside[idx] {
                indent(depth + 1, out);
                out.push_str(line);
                out.push('\n');
            }
            for c in &l.body {
                emit_node(k, ann, c, depth + 1, out);
            }
            indent(depth, out);
            out.push_str("}\n");
        }
        Node::Stmt(s) => {
            let (rhs, unused) = stmt_rhs(k, s);
            let first = access_c(k, &s.writes[0]);
            indent(depth, out);
            out.push_str(&format!("/* {} */ {first} = {rhs};\n", s.name));
            // extra writes observe the same value (multi-write summary
            // statements; none in the shipped corpus, legal in the IR)
            for w in &s.writes[1..] {
                indent(depth, out);
                out.push_str(&format!("{} = {first};\n", access_c(k, w)));
            }
            // reads the op fold could not reach (fewer ops than reads —
            // possible for hand-written `.knl` with a short/absent `ops`
            // clause) stay live as `(void)` reads, keeping the emission
            // access-exact without inventing ops the model never scored
            for r in unused {
                indent(depth, out);
                out.push_str(&format!("(void){r};\n"));
            }
        }
    }
}

/// Representative right-hand side: fold the reads over the op chain.
/// Reductions put the self-read (accumulator) last; statements with no
/// reads and no ops are initializations (`= 0`).
///
/// Returns the expression plus any reads the fold could not consume
/// (fewer ops than reads): the caller emits those as `(void)` reads so
/// every declared access survives into the C.
fn stmt_rhs(k: &Kernel, s: &Stmt) -> (String, Vec<String>) {
    let write = s.writes.first();
    let is_self = |r: &Access| write.is_some_and(|w| r == w);
    let self_read: Option<String> = s.reads.iter().find(|r| is_self(r)).map(|r| access_c(k, r));
    let others: Vec<String> = s
        .reads
        .iter()
        .filter(|r| !is_self(r))
        .map(|r| access_c(k, r))
        .collect();

    if s.reads.is_empty() && s.chain.is_empty() {
        return ("0".into(), Vec::new());
    }

    let (operands, tail) = match (&self_read, others.is_empty()) {
        // reduction with other operands: fold others, accumulate last
        (Some(acc), false) => (others, Some(acc.clone())),
        // everything else: fold all reads (or a unit constant) in order
        _ => {
            let all: Vec<String> = s.reads.iter().map(|r| access_c(k, r)).collect();
            (if all.is_empty() { vec!["1".into()] } else { all }, None)
        }
    };

    let chain = &s.chain;
    let fold_ops = match &tail {
        Some(_) if !chain.is_empty() => &chain[..chain.len() - 1],
        _ => &chain[..],
    };
    let mut used = vec![false; operands.len()];
    used[0] = true;
    let mut expr = operands[0].clone();
    for (j, op) in fold_ops.iter().enumerate() {
        let idx = (j + 1) % operands.len();
        used[idx] = true;
        expr = format!("({expr} {} {})", op.name(), operands[idx]);
    }
    if let Some(acc) = tail {
        match chain.last() {
            Some(op) => expr = format!("({expr} {} {acc})", op.name()),
            None => {
                // self-read, no ops: a copy — the fold start never made
                // it into the expression, so hand it back as unconsumed
                used[0] = false;
                expr = acc;
            }
        }
    }
    let unused: Vec<String> = operands
        .into_iter()
        .zip(used)
        .filter(|(_, u)| !u)
        .map(|(o, _)| o)
        .collect();
    // the fold always parenthesizes fully; drop the redundant outer pair
    let expr = if expr.starts_with('(') && expr.ends_with(')') {
        expr[1..expr.len() - 1].to_string()
    } else {
        expr
    };
    (expr, unused)
}

/// `array[idx0][idx1]...` with affine indices over iterator names.
fn access_c(k: &Kernel, a: &Access) -> String {
    let idx: String = a
        .indices
        .iter()
        .map(|e| format!("[{}]", affine_c(k, e)))
        .collect();
    format!("{}{idx}", k.array(a.array).name)
}

/// Affine expression in C syntax over loop *names* — same rendering as
/// the `.knl` pretty-printer (which is already valid C arithmetic).
fn affine_c(k: &Kernel, e: &AffineExpr) -> String {
    let mut out = String::new();
    let mut first = true;
    for &(l, c) in &e.terms {
        let name = k.loop_name(l);
        if first {
            if c == 1 {
                out.push_str(name);
            } else if c == -1 {
                out.push_str(&format!("-{name}"));
            } else {
                out.push_str(&format!("{c} * {name}"));
            }
            first = false;
        } else if c == 1 {
            out.push_str(&format!(" + {name}"));
        } else if c == -1 {
            out.push_str(&format!(" - {name}"));
        } else if c > 0 {
            out.push_str(&format!(" + {c} * {name}"));
        } else {
            out.push_str(&format!(" - {} * {name}", -c));
        }
    }
    if first {
        out.push_str(&format!("{}", e.constant));
    } else if e.constant > 0 {
        out.push_str(&format!(" + {}", e.constant));
    } else if e.constant < 0 {
        out.push_str(&format!(" - {}", -e.constant));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::codegen::{self, EmitConfig};
    use crate::ir::DType;
    use crate::pragma::Design;

    fn plain(name: &str) -> String {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = crate::poly::Analysis::new(&k);
        let dev = crate::hls::Device::u200();
        codegen::emit(&k, &a, &dev, &Design::empty(&k), &EmitConfig::default())
    }

    #[test]
    fn fn_names_are_c_identifiers() {
        assert_eq!(c_fn_name("2mm"), "kernel_2mm");
        assert_eq!(c_fn_name("floyd-warshall"), "kernel_floyd_warshall");
        assert_eq!(c_fn_name("gemm"), "kernel_gemm");
    }

    #[test]
    fn gemm_signature_and_loops() {
        let code = plain("gemm");
        assert!(code.contains("void kernel_gemm("), "{code}");
        assert!(code.contains("float C[60][70]"), "{code}");
        assert!(code.contains("const float A[60][80]"), "{code}");
        assert!(code.contains("for (int i = 0; i < 60; i++) {"), "{code}");
        // the update statement reads itself -> accumulator last
        assert!(code.contains("/* S1 */ C[i][j1] = "), "{code}");
        assert!(code.contains("+ C[i][j1];"), "{code}");
    }

    #[test]
    fn init_statements_assign_zero() {
        // only pure no-read/no-op statements emit `= 0` (gemm's S0
        // scales C in PolyBench but reads itself in the summary IR)
        let cnn = benchmarks::build("cnn", Size::Medium, DType::F32).unwrap();
        let a = crate::poly::Analysis::new(&cnn);
        let dev = crate::hls::Device::u200();
        let ccode = codegen::emit(&cnn, &a, &dev, &Design::empty(&cnn), &EmitConfig::default());
        assert!(ccode.contains("/* S0 */ out[j][h][w] = 0;"), "{ccode}");
        assert!(
            ccode.contains(
                "/* S1 */ out[j][h][w] = (in[i][h + p][w + q] * weight[j][i][p][q]) + out[j][h][w];"
            ),
            "{ccode}"
        );
    }

    #[test]
    fn short_op_chains_keep_every_read_live() {
        use crate::ir::{ArrayDir, KernelBuilder};
        let mut kb = KernelBuilder::new("copyish", DType::F32);
        let a = kb.array("a", &[8], ArrayDir::Out);
        let b = kb.array("b", &[8], ArrayDir::In);
        let cc = kb.array("c", &[8], ArrayDir::In);
        kb.for_const("i", 0, 8, |kb, i| {
            // two reads, no ops: the fold can only consume one read
            kb.stmt(
                "S0",
                vec![kb.at(a, &[kb.v(i)])],
                vec![kb.at(b, &[kb.v(i)]), kb.at(cc, &[kb.v(i)])],
                &[],
            );
        });
        let k = kb.finish();
        let an = crate::poly::Analysis::new(&k);
        let dev = crate::hls::Device::u200();
        let code = codegen::emit(&k, &an, &dev, &Design::empty(&k), &EmitConfig::default());
        assert!(code.contains("/* S0 */ a[i] = b[i];"), "{code}");
        assert!(code.contains("(void)c[i];"), "{code}");
        codegen::lint(&k, &code).unwrap();
    }

    #[test]
    fn triangular_bounds_render_over_iterator_names() {
        let code = plain("lu");
        assert!(code.contains("for (int j0 = 0; j0 < i; j0++) {"), "{code}");
    }

    #[test]
    fn temp_arrays_are_static_locals() {
        let code = plain("2mm");
        assert!(code.contains("static float tmp[40][50];"), "{code}");
        assert!(!code.contains("float tmp[40][50],"), "{code}");
    }
}
