//! Structural re-parse of emitted C: the invariants every emission must
//! satisfy, checked without a C compiler (none exists in the offline
//! environment).
//!
//! [`lint`] verifies, against the kernel the code was emitted from:
//!
//! 1. **balanced delimiters** — `{}`/`[]`/`()` match with comments
//!    stripped (an unbalanced emission cannot be compilable C);
//! 2. **loop coverage** — exactly one `for (` header per IR loop;
//! 3. **statement coverage** — every statement name appears as a
//!    `/* name */` marker, and at least one `;`-terminated assignment
//!    per statement exists;
//! 4. **pragma attachment** — every loop-level pragma line is adjacent
//!    to a loop: Merlin `#pragma ACCEL` lines (other than `cache`,
//!    which also binds to the following loop) are followed by a `for`
//!    header, Vitis loop pragmas immediately follow one;
//! 5. **pragma well-formedness** — every `#pragma` line is either
//!    `#pragma ACCEL …` or `#pragma HLS …`.
//!
//! The golden-file suite and the generative fuzz suite both run every
//! emission through this before comparing bytes.

use crate::ir::Kernel;

/// Counts gathered while linting (handy for test assertions).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LintReport {
    /// `for (` loop headers found.
    pub for_loops: usize,
    /// `#pragma` lines found.
    pub pragmas: usize,
    /// `/* name */` statement markers found.
    pub stmt_markers: usize,
}

/// Check `code` against the kernel it claims to implement. Returns the
/// lint counts, or a description of the first violated invariant.
pub fn lint(k: &Kernel, code: &str) -> Result<LintReport, String> {
    let stripped = strip_comments(code);

    // 1. balanced delimiters
    let mut stack: Vec<char> = Vec::new();
    for (i, ch) in stripped.chars().enumerate() {
        match ch {
            '(' | '[' | '{' => stack.push(ch),
            ')' | ']' | '}' => {
                let want = match ch {
                    ')' => '(',
                    ']' => '[',
                    _ => '{',
                };
                if stack.pop() != Some(want) {
                    return Err(format!("unbalanced `{ch}` at byte {i}"));
                }
            }
            _ => {}
        }
    }
    if let Some(open) = stack.pop() {
        return Err(format!("unclosed `{open}`"));
    }

    let mut report = LintReport {
        for_loops: stripped.matches("for (").count(),
        ..LintReport::default()
    };

    // 2. one `for (` per IR loop
    if report.for_loops != k.n_loops() {
        return Err(format!(
            "{} `for (` headers for {} IR loops",
            report.for_loops,
            k.n_loops()
        ));
    }

    // 3. every statement appears (markers live in comments: scan `code`)
    for s in k.stmts() {
        let marker = format!("/* {} */", s.name);
        if !code.contains(&marker) {
            return Err(format!("statement marker `{marker}` missing"));
        }
        report.stmt_markers += 1;
    }
    if stripped.matches(';').count() < k.n_stmts() {
        return Err("fewer `;` than statements".into());
    }

    // 4 + 5. pragma shape and attachment
    let lines: Vec<&str> = code.lines().map(str::trim_start).collect();
    for (i, line) in lines.iter().enumerate() {
        if !line.starts_with("#pragma") {
            continue;
        }
        report.pragmas += 1;
        let is_accel = line.starts_with("#pragma ACCEL ");
        let is_hls = line.starts_with("#pragma HLS ");
        if !is_accel && !is_hls {
            return Err(format!("malformed pragma line `{line}`"));
        }
        if is_accel {
            // next non-pragma/non-comment line must open a loop
            let mut j = i + 1;
            while j < lines.len()
                && (lines[j].starts_with("#pragma") || lines[j].starts_with("//"))
            {
                j += 1;
            }
            if j >= lines.len() || !lines[j].starts_with("for (") {
                return Err(format!("`{line}` not attached to a loop header"));
            }
        }
        if is_hls && !line.contains("array_partition") {
            // loop-body placement: the nearest preceding non-pragma,
            // non-comment line must be a `for (...) {` header
            let mut j = i;
            loop {
                if j == 0 {
                    return Err(format!("`{line}` has no enclosing loop header"));
                }
                j -= 1;
                let prev = lines[j];
                if prev.starts_with("#pragma") || prev.starts_with("//") || prev.is_empty() {
                    continue;
                }
                if prev.starts_with("for (") && prev.ends_with('{') {
                    break;
                }
                return Err(format!("`{line}` not placed directly inside a loop"));
            }
        }
    }

    Ok(report)
}

/// Remove `//` and `/* */` comments (emitted code has no string
/// literals, so a naive scan is exact).
fn strip_comments(code: &str) -> String {
    let bytes = code.as_bytes();
    let mut out = String::with_capacity(code.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'/' && i + 1 < bytes.len() && bytes[i + 1] == b'/' {
            while i < bytes.len() && bytes[i] != b'\n' {
                i += 1;
            }
        } else if bytes[i] == b'/' && i + 1 < bytes.len() && bytes[i + 1] == b'*' {
            i += 2;
            while i + 1 < bytes.len() && !(bytes[i] == b'*' && bytes[i + 1] == b'/') {
                i += 1;
            }
            i = (i + 2).min(bytes.len());
        } else {
            out.push(bytes[i] as char);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::codegen::{self, Dialect, EmitConfig};
    use crate::hls::Device;
    use crate::ir::DType;
    use crate::poly::Analysis;
    use crate::pragma::Design;

    fn emit(name: &str, dialect: Dialect) -> (crate::ir::Kernel, String) {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let mut d = Design::empty(&k);
        for i in 0..k.n_loops() {
            if k.loops[i].innermost {
                d.pragmas[i].pipeline = true;
            }
        }
        let code = codegen::emit(
            &k,
            &a,
            &dev,
            &d,
            &EmitConfig {
                dialect,
                realized: false,
            },
        );
        (k, code)
    }

    #[test]
    fn clean_emissions_lint() {
        for name in ["gemm", "2mm", "lu", "jacobi-2d"] {
            for dialect in [Dialect::Merlin, Dialect::Vitis] {
                let (k, code) = emit(name, dialect);
                let rep = lint(&k, &code).unwrap_or_else(|e| panic!("{name}: {e}\n{code}"));
                assert_eq!(rep.for_loops, k.n_loops(), "{name}");
                assert!(rep.pragmas > 0, "{name}");
            }
        }
    }

    #[test]
    fn mutilated_code_is_rejected() {
        let (k, code) = emit("gemm", Dialect::Merlin);
        let unbalanced = code.replacen('}', "", 1);
        assert!(lint(&k, &unbalanced).is_err());
        let no_loop = code.replacen("for (", "while (", 1);
        assert!(lint(&k, &no_loop).is_err());
        let floating = format!("#pragma ACCEL pipeline\n{code}");
        assert!(lint(&k, &floating).is_err());
        let bad = code.replace("#pragma ACCEL cache", "#pragma WEIRD cache");
        assert!(lint(&k, &bad).is_err());
    }

    #[test]
    fn strip_comments_removes_both_styles() {
        let s = strip_comments("a /* x { */ b // y }\nc");
        assert_eq!(s, "a  b \nc");
    }
}
