"""L2 model + AOT lowering tests: shapes, argmin head, HLO text emission."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import lat_bound as lb


@pytest.fixture(scope="module")
def io():
    rng = np.random.default_rng(7)
    loops = rng.uniform(0, 4, (model.BATCH, lb.UNITS, lb.LOOPS, lb.F))
    loops[..., 0] = rng.integers(1, 500, loops.shape[:-1])
    loops[..., 1] = 1
    loops[..., 5] = 1
    units = rng.uniform(0, 10, (model.BATCH, lb.UNITS, lb.G))
    units[..., 6] = 1
    units[..., 7] = 1
    return loops, units


def test_eval_batch_shape(io):
    loops, units = io
    (out,) = model.eval_batch(loops, units)
    assert out.shape == (model.BATCH, 2)
    assert out.dtype == np.float64


def test_argmin_head_consistent(io):
    loops, units = io
    out, idx, lat = model.eval_argmin(loops, units)
    out = np.asarray(out)
    assert int(idx) == int(np.argmin(out[:, 0]))
    assert float(lat) == pytest.approx(float(out[:, 0].min()))


def test_hlo_text_lowering(io, tmp_path):
    lowered = jax.jit(model.eval_batch).lower(*model.example_args())
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # HLO text (not serialized proto) is the contract with the Rust side
    assert "f64[512,16,8,6]" in text.replace(" ", "")


def test_aot_main_writes_artifacts(tmp_path, monkeypatch):
    import sys

    monkeypatch.setattr(
        sys, "argv", ["aot", "--out-dir", str(tmp_path), "--batch", "128"]
    )
    aot.main()
    assert (tmp_path / "lat_bound.hlo.txt").exists()
    assert (tmp_path / "lat_argmin.hlo.txt").exists()
    assert (tmp_path / "abi.json").exists()
    text = (tmp_path / "lat_bound.hlo.txt").read_text()
    assert text.startswith("HloModule")


def test_block_divisibility_guard():
    with pytest.raises(AssertionError):
        lb.lat_bound(
            np.zeros((100, lb.UNITS, lb.LOOPS, lb.F)),
            np.zeros((100, lb.UNITS, lb.G)),
            batch=100,
        )
