"""Pallas kernel vs pure-jnp/numpy oracle — the core L1 correctness signal.

hypothesis sweeps feature-tensor contents (and, indirectly, the masked
formula's edge cases: invalid rows, uf=1 log2 terms, empty max-sets).
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lat_bound as lb
from compile.kernels import ref


def random_features(rng: np.random.Generator, batch: int):
    loops = np.zeros((batch, lb.UNITS, lb.LOOPS, lb.F))
    units = np.zeros((batch, lb.UNITS, lb.G))
    shape = loops.shape[:-1]
    loops[..., 0] = rng.integers(1, 2101, shape)  # tc
    loops[..., 1] = 2 ** rng.integers(0, 8, shape)  # uf
    role = rng.integers(0, 4, shape)  # exclusive role flags
    loops[..., 2] = role == 1
    loops[..., 3] = role == 2
    loops[..., 4] = role == 3
    loops[..., 5] = rng.integers(0, 2, shape)  # valid
    ushape = units.shape[:-1]
    units[..., 0] = rng.uniform(0.0, 40.0, ushape)  # il_base
    units[..., 1] = rng.choice([0.0, 3.0, 4.0, 12.0], ushape)  # il_red
    units[..., 2] = rng.choice([0.0, 1.0, 4.0, 12.0], ushape)  # ii
    units[..., 3] = rng.integers(1, 2101, ushape)  # pipe_tc
    units[..., 4] = 2 ** rng.integers(0, 6, ushape)  # pipe_uf
    units[..., 5] = rng.uniform(0.0, 16.0, ushape)  # dsp_base
    units[..., 6] = rng.integers(0, 2, ushape)  # w_sum
    units[..., 7] = rng.integers(0, 2, ushape)  # valid
    return loops, units


@pytest.fixture(scope="module")
def batch_io():
    rng = np.random.default_rng(1234)
    return random_features(rng, lb.BATCH)


def test_kernel_matches_jnp_ref(batch_io):
    loops, units = batch_io
    out_k = np.asarray(lb.lat_bound(loops, units))
    out_r = np.asarray(ref.lat_bound_ref(loops, units))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-12, atol=0)


def test_kernel_matches_numpy_ref(batch_io):
    loops, units = batch_io
    out_k = np.asarray(lb.lat_bound(loops, units))
    out_n = ref.numpy_ref(loops, units)
    np.testing.assert_allclose(out_k, out_n, rtol=1e-12, atol=1e-9)


def test_outputs_finite_nonnegative(batch_io):
    loops, units = batch_io
    out = np.asarray(lb.lat_bound(loops, units))
    assert np.all(np.isfinite(out))
    assert np.all(out >= 0.0)


def test_zero_features_zero_latency():
    loops = np.zeros((lb.BATCH, lb.UNITS, lb.LOOPS, lb.F))
    units = np.zeros((lb.BATCH, lb.UNITS, lb.G))
    out = np.asarray(lb.lat_bound(loops, units))
    np.testing.assert_array_equal(out, 0.0)


def test_single_sum_unit_formula():
    """Hand-checkable case: one unit, above=tc/uf, il=5, ii=1 ramp."""
    loops = np.zeros((lb.BATCH, lb.UNITS, lb.LOOPS, lb.F))
    units = np.zeros((lb.BATCH, lb.UNITS, lb.G))
    # unit 0: one above_par row tc=100 uf=4, il_base=5, ii=1,
    # pipe_tc=50, pipe_uf=2
    loops[0, 0, 0] = [100, 4, 1, 0, 0, 1]
    units[0, 0] = [5, 0, 1, 50, 2, 2, 1, 1]
    out = np.asarray(lb.lat_bound(loops, units))
    above = 100 / 4
    expect_lat = above * (5 + 1 * (50 / 2 - 1))
    expect_dsp = 2 * 4 / 1
    assert out[0, 0] == pytest.approx(expect_lat)
    assert out[0, 1] == pytest.approx(expect_dsp)


def test_tree_reduction_term():
    """under_red row: (tc/uf) * ceil(log2 uf)."""
    loops = np.zeros((lb.BATCH, lb.UNITS, lb.LOOPS, lb.F))
    units = np.zeros((lb.BATCH, lb.UNITS, lb.G))
    loops[0, 0, 0] = [2100, 700, 0, 0, 1, 1]
    units[0, 0] = [6, 4, 0, 1, 1, 0, 1, 1]
    out = np.asarray(lb.lat_bound(loops, units))
    tree = (2100 / 700) * np.ceil(np.log2(700))
    assert out[0, 0] == pytest.approx(6 + 4 * tree)


def test_max_set_takes_max_not_sum():
    loops = np.zeros((lb.BATCH, lb.UNITS, lb.LOOPS, lb.F))
    units = np.zeros((lb.BATCH, lb.UNITS, lb.G))
    units[0, 0] = [100, 0, 0, 1, 1, 0, 0, 1]  # max-set
    units[0, 1] = [70, 0, 0, 1, 1, 0, 0, 1]  # max-set
    units[0, 2] = [5, 0, 0, 1, 1, 0, 1, 1]  # sum
    out = np.asarray(lb.lat_bound(loops, units))
    assert out[0, 0] == pytest.approx(105.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_kernel_vs_numpy(seed):
    rng = np.random.default_rng(seed)
    loops, units = random_features(rng, lb.BATCH)
    out_k = np.asarray(lb.lat_bound(loops, units))
    out_n = ref.numpy_ref(loops, units)
    np.testing.assert_allclose(out_k, out_n, rtol=1e-11, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_dtype_f32_close(seed):
    """f32 inputs run too (upcast behaviour) and stay close to the f64
    oracle — guards against dtype-dependent surprises in the kernel."""
    rng = np.random.default_rng(seed)
    loops, units = random_features(rng, lb.BATCH)
    out32 = np.asarray(
        lb.lat_bound(loops.astype(np.float32), units.astype(np.float32))
    )
    out64 = ref.numpy_ref(loops, units)
    np.testing.assert_allclose(out32, out64, rtol=2e-4, atol=1.0)
