"""L2 — the jitted compute graph the Rust runtime executes.

Two entry points, both lowered AOT by ``aot.py``:

* ``eval_batch(loops, units)`` — the Pallas lower-bound kernel over a
  fixed batch (the DSE's bulk pruning primitive);
* ``eval_argmin(loops, units)`` — the same plus an argmin head, returning
  ``(out[B,2], best_idx[1], best_lat[1])`` so the coordinator can pick a
  wave's most promising candidate without shipping the whole batch back.

Python here runs only at build time (``make artifacts``); the request path
executes the lowered HLO through PJRT from Rust.
"""

import jax
import jax.numpy as jnp

from .kernels import lat_bound as lb

jax.config.update("jax_enable_x64", True)

BATCH = lb.BATCH


def eval_batch(loops, units):
    """(loops[B,U,L,F], units[B,U,G]) -> out[B,2]; returned as a 1-tuple
    for the HLO-text interchange convention (return_tuple=True)."""
    return (lb.lat_bound(loops, units, batch=BATCH),)


def eval_argmin(loops, units):
    """Batch evaluation + argmin head: (out[B,2], idx[], lat[])."""
    out = lb.lat_bound(loops, units, batch=BATCH)
    lat = out[:, 0]
    idx = jnp.argmin(lat)
    return (out, idx.astype(jnp.int64), lat[idx])


def example_args(batch=BATCH):
    spec = jax.ShapeDtypeStruct(
        (batch, lb.UNITS, lb.LOOPS, lb.F), jnp.float64
    )
    spec_u = jax.ShapeDtypeStruct((batch, lb.UNITS, lb.G), jnp.float64)
    return spec, spec_u
