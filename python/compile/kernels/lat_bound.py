"""L1 — Pallas kernel: batched latency/resource lower-bound evaluation.

Evaluates the paper's Section-5.4 objective for a batch of encoded designs.
The ABI matches ``rust/src/model/features.rs`` exactly:

  loops[B, U, L, F]  per-loop rows: tc, uf, above_par, above_seq,
                     under_red, valid
  units[B, U, G]     per-unit scalars: il_base, il_red, ii, pipe_tc,
                     pipe_uf, dsp_base, w_sum, valid
  out[B, 2]          latency lower bound (cycles), optimistic DSP

Per unit u:

  above = prod_l [above_par: tc/uf] * prod_l [above_seq: tc]
  tree  = prod_l [under_red: (tc/uf) * max(1, ceil(log2 uf))]
  lat_u = above * (il_base + il_red*tree + ii*max(pipe_tc/pipe_uf - 1, 0))
  mcu   = prod_l uf
  dsp_u = dsp_base * mcu / max(ii, 1)

  latency = sum_{w_sum} lat_u + max_{!w_sum} lat_u
  dsp     = max_u dsp_u

TPU-shaping notes (DESIGN.md §3): the computation is a masked reduction
over a fixed [U, L, F] stencil per design — we tile over the batch axis
only (``BLOCK_B`` designs per grid step), keeping each block's operand
slice (BLOCK_B*U*L*F*8B ≈ 400 kB at BLOCK_B=64) comfortably inside VMEM.
``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against ``ref.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ABI constants — keep in sync with rust/src/model/features.rs (Abi).
UNITS = 16
LOOPS = 8
F = 6
G = 8
BATCH = 512
BLOCK_B = 64


def _unit_math(loops_blk, units_blk):
    """Shared formula over one block: loops[b,U,L,F], units[b,U,G] ->
    (lat[b], dsp[b])."""
    tc = loops_blk[..., 0]
    uf = jnp.maximum(loops_blk[..., 1], 1.0)
    above_par = loops_blk[..., 2]
    above_seq = loops_blk[..., 3]
    under_red = loops_blk[..., 4]
    valid_row = loops_blk[..., 5]

    # masked per-row factors (invalid rows contribute 1)
    f_par = jnp.where((above_par > 0) & (valid_row > 0), tc / uf, 1.0)
    f_seq = jnp.where((above_seq > 0) & (valid_row > 0), tc, 1.0)
    levels = jnp.maximum(jnp.ceil(jnp.log2(uf)), 1.0)
    f_red = jnp.where((under_red > 0) & (valid_row > 0), tc / uf * levels, 1.0)
    f_mcu = jnp.where(valid_row > 0, uf, 1.0)

    above = jnp.prod(f_par, axis=-1) * jnp.prod(f_seq, axis=-1)  # [b, U]
    tree = jnp.prod(f_red, axis=-1)
    mcu = jnp.prod(f_mcu, axis=-1)

    il_base = units_blk[..., 0]
    il_red = units_blk[..., 1]
    ii = units_blk[..., 2]
    pipe_tc = jnp.maximum(units_blk[..., 3], 1.0)
    pipe_uf = jnp.maximum(units_blk[..., 4], 1.0)
    dsp_base = units_blk[..., 5]
    w_sum = units_blk[..., 6]
    valid = units_blk[..., 7]

    il = il_base + il_red * tree
    ramp = ii * jnp.maximum(pipe_tc / pipe_uf - 1.0, 0.0)
    lat_u = above * (il + ramp)

    lat_sum = jnp.sum(jnp.where((valid > 0) & (w_sum > 0), lat_u, 0.0), axis=-1)
    lat_max = jnp.max(
        jnp.where((valid > 0) & (w_sum == 0), lat_u, 0.0), axis=-1
    )
    dsp = jnp.max(
        jnp.where(valid > 0, dsp_base * mcu / jnp.maximum(ii, 1.0), 0.0),
        axis=-1,
    )
    return lat_sum + lat_max, dsp


def _kernel(loops_ref, units_ref, out_ref):
    loops_blk = loops_ref[...]  # [BLOCK_B, U, L, F]
    units_blk = units_ref[...]  # [BLOCK_B, U, G]
    lat, dsp = _unit_math(loops_blk, units_blk)
    out_ref[...] = jnp.stack([lat, dsp], axis=-1)


@functools.partial(jax.jit, static_argnames=("batch",))
def lat_bound(loops, units, batch=BATCH):
    """Batched lower-bound evaluation via the Pallas kernel.

    loops: f64[batch, UNITS, LOOPS, F]; units: f64[batch, UNITS, G]
    returns f64[batch, 2] — (latency cycles, DSP).
    """
    assert batch % BLOCK_B == 0, "batch must be a multiple of BLOCK_B"
    grid = (batch // BLOCK_B,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, UNITS, LOOPS, F), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((BLOCK_B, UNITS, G), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, UNITS // UNITS * 2), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, 2), loops.dtype),
        interpret=True,
    )(loops, units)
