"""Pure-jnp correctness oracle for the Pallas kernel.

Implements the identical unit formula with plain vectorized jnp — no
pallas, no grid. ``lat_bound_ref(loops, units)`` must match
``lat_bound.lat_bound`` bit-for-bit on f64 inputs (same op order), and both
must match the Rust reference ``model::features::eval_features`` to 1e-6
relative (checked from the Rust side in integration_runtime.rs).
"""

import jax.numpy as jnp


def lat_bound_ref(loops, units):
    """loops: f64[B, U, L, F]; units: f64[B, U, G] -> f64[B, 2]."""
    tc = loops[..., 0]
    uf = jnp.maximum(loops[..., 1], 1.0)
    above_par = loops[..., 2]
    above_seq = loops[..., 3]
    under_red = loops[..., 4]
    valid_row = loops[..., 5]

    f_par = jnp.where((above_par > 0) & (valid_row > 0), tc / uf, 1.0)
    f_seq = jnp.where((above_seq > 0) & (valid_row > 0), tc, 1.0)
    levels = jnp.maximum(jnp.ceil(jnp.log2(uf)), 1.0)
    f_red = jnp.where((under_red > 0) & (valid_row > 0), tc / uf * levels, 1.0)
    f_mcu = jnp.where(valid_row > 0, uf, 1.0)

    above = jnp.prod(f_par, axis=-1) * jnp.prod(f_seq, axis=-1)
    tree = jnp.prod(f_red, axis=-1)
    mcu = jnp.prod(f_mcu, axis=-1)

    il_base = units[..., 0]
    il_red = units[..., 1]
    ii = units[..., 2]
    pipe_tc = jnp.maximum(units[..., 3], 1.0)
    pipe_uf = jnp.maximum(units[..., 4], 1.0)
    dsp_base = units[..., 5]
    w_sum = units[..., 6]
    valid = units[..., 7]

    il = il_base + il_red * tree
    ramp = ii * jnp.maximum(pipe_tc / pipe_uf - 1.0, 0.0)
    lat_u = above * (il + ramp)

    lat_sum = jnp.sum(jnp.where((valid > 0) & (w_sum > 0), lat_u, 0.0), axis=-1)
    lat_max = jnp.max(jnp.where((valid > 0) & (w_sum == 0), lat_u, 0.0), axis=-1)
    dsp = jnp.max(
        jnp.where(valid > 0, dsp_base * mcu / jnp.maximum(ii, 1.0), 0.0), axis=-1
    )
    return jnp.stack([lat_sum + lat_max, dsp], axis=-1)


def numpy_ref(loops, units):
    """NumPy twin used by hypothesis tests without tracing overhead."""
    import numpy as np

    loops = np.asarray(loops, dtype=np.float64)
    units = np.asarray(units, dtype=np.float64)
    tc = loops[..., 0]
    uf = np.maximum(loops[..., 1], 1.0)
    f_par = np.where((loops[..., 2] > 0) & (loops[..., 5] > 0), tc / uf, 1.0)
    f_seq = np.where((loops[..., 3] > 0) & (loops[..., 5] > 0), tc, 1.0)
    levels = np.maximum(np.ceil(np.log2(uf)), 1.0)
    f_red = np.where(
        (loops[..., 4] > 0) & (loops[..., 5] > 0), tc / uf * levels, 1.0
    )
    f_mcu = np.where(loops[..., 5] > 0, uf, 1.0)
    above = f_par.prod(-1) * f_seq.prod(-1)
    tree = f_red.prod(-1)
    mcu = f_mcu.prod(-1)
    il = units[..., 0] + units[..., 1] * tree
    ramp = units[..., 2] * np.maximum(
        np.maximum(units[..., 3], 1.0) / np.maximum(units[..., 4], 1.0) - 1.0, 0.0
    )
    lat_u = above * (il + ramp)
    valid = units[..., 7] > 0
    w_sum = units[..., 6] > 0
    lat_sum = np.where(valid & w_sum, lat_u, 0.0).sum(-1)
    lat_max = np.where(valid & ~w_sum, lat_u, 0.0).max(-1)
    dsp = np.where(
        valid, units[..., 5] * mcu / np.maximum(units[..., 2], 1.0), 0.0
    ).max(-1)
    return np.stack([lat_sum + lat_max, dsp], axis=-1)
