"""L1 Pallas kernels for the NLP-DSE compute hot-spot (bulk lower-bound
evaluation) plus their pure-jnp oracles."""

from . import lat_bound, ref  # noqa: F401
