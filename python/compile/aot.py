"""AOT lowering: jit → StableHLO → XLA computation → **HLO text**.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the published xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target). Also writes ``abi.json`` describing the tensor
shapes so the Rust runtime can sanity-check at load time.
"""

import argparse
import json
import os

import jax

from . import model


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=model.BATCH)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    specs = model.example_args(args.batch)

    targets = {
        "lat_bound": model.eval_batch,
        "lat_argmin": model.eval_argmin,
    }
    from .kernels import lat_bound as lb

    for name, fn in targets.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")

    abi = {
        "batch": args.batch,
        "units": lb.UNITS,
        "loops": lb.LOOPS,
        "f": lb.F,
        "g": lb.G,
        "dtype": "f64",
        "outputs": {"lat_bound": "[B,2]", "lat_argmin": "[B,2], idx, lat"},
    }
    with open(os.path.join(args.out_dir, "abi.json"), "w") as f:
        json.dump(abi, f, indent=2)
    print("wrote abi.json")


if __name__ == "__main__":
    main()
