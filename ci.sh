#!/usr/bin/env bash
# Tier-1 verification: format, lint, build, test.
# Usage: ./ci.sh  (from the repository root)
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

echo "==> cargo build --release (all targets: lib, bin, benches, examples, tests)"
cargo build --release --workspace --all-targets

echo "==> cargo test -q"
cargo test -q --workspace

echo "==> cargo doc --no-deps (rustdoc warnings are errors: missing docs, broken intra-doc links)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace

echo "==> doc-tests (the GUIDE/rustdoc examples must keep running as written)"
cargo test -q --workspace --doc

echo "==> fuzz smoke (FUZZ_SMOKE=1 — generative differential suites at bounded N)"
# mirrors BENCH_SMOKE: a fast bounded re-run that keeps the env-knob
# replay path (FUZZ_SMOKE / FUZZ_KERNELS / FUZZ_SEED) from rotting; the
# full-N suites (N >= 100 kernels per mode) already ran in `cargo test`
# above. --nocapture so the logged seed ranges land in the CI output.
FUZZ_SMOKE=1 cargo test -q --test property_frontend_fuzz -- --nocapture

echo "==> bench smoke (smallest sizes, BENCH_MS=25 — benches can't rot)"
rm -f BENCH_solver.json  # a stale file must not satisfy the emission check
for bench in bench_tables bench_model_eval bench_nlp_solver bench_space_enum bench_runtime_batch bench_codegen; do
  BENCH_SMOKE=1 BENCH_MS=25 cargo bench --bench "$bench"
done
if [ ! -f BENCH_solver.json ]; then
  echo "ci: bench_nlp_solver did not emit BENCH_solver.json at the repo root" >&2
  exit 1
fi
echo "    BENCH_solver.json emitted"

echo "ci: all checks passed"
