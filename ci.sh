#!/usr/bin/env bash
# Tier-1 verification: format, lint, build, test.
# Usage: ./ci.sh  (from the repository root)
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

echo "==> cargo build --release (all targets: lib, bin, benches, examples, tests)"
cargo build --release --workspace --all-targets

echo "==> cargo test -q"
cargo test -q --workspace

echo "ci: all checks passed"
