#!/usr/bin/env bash
# Tier-1 verification: format, lint, build, test.
# Usage: ./ci.sh  (from the repository root)
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

echo "==> cargo build --release (all targets: lib, bin, benches, examples, tests)"
cargo build --release --workspace --all-targets

echo "==> cargo test -q"
cargo test -q --workspace

echo "==> cargo doc --no-deps (rustdoc warnings are errors: missing docs, broken intra-doc links)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace

echo "==> doc-tests (the GUIDE/rustdoc examples must keep running as written)"
cargo test -q --workspace --doc

echo "==> fuzz smoke (FUZZ_SMOKE=1 — generative differential suites at bounded N)"
# mirrors BENCH_SMOKE: a fast bounded re-run that keeps the env-knob
# replay path (FUZZ_SMOKE / FUZZ_KERNELS / FUZZ_SEED) from rotting; the
# full-N suites (N >= 100 kernels per mode) already ran in `cargo test`
# above. --nocapture so the logged seed ranges land in the CI output.
FUZZ_SMOKE=1 cargo test -q --test property_frontend_fuzz -- --nocapture
FUZZ_SMOKE=1 cargo test -q --test property_fingerprint -- --nocapture
FUZZ_SMOKE=1 cargo test -q --test property_deps -- --nocapture
FUZZ_SMOKE=1 cargo test -q --test property_surrogate -- --nocapture

echo "==> transform fuzz smoke (TRANSFORM_FUZZ=1 — full-width variant suites at bounded N)"
# the transform suites self-cap at 12 kernels under plain `cargo test`;
# TRANSFORM_FUZZ=1 lifts the cap to the FUZZ_KERNELS width, and pairing
# it with FUZZ_SMOKE keeps the CI cost bounded while exercising the
# widened path (replay: TRANSFORM_FUZZ=1 FUZZ_SEED=… FUZZ_KERNELS=1).
TRANSFORM_FUZZ=1 FUZZ_SMOKE=1 cargo test -q --test property_frontend_fuzz prop_transform_ -- --nocapture

echo "==> serve smoke (SERVE_SMOKE=1 — real daemon: solve, cache hit, stats, SIGTERM)"
# Drives the release binary end to end over TCP: start `serve` on an
# ephemeral port, parse the bound port from the banner, issue the same
# solve twice (miss then hit), check `stats` counted the hit, then
# SIGTERM and require a clean exit. Uses bash's /dev/tcp so no netcat
# is needed. Skip with SERVE_SMOKE=0 (sandboxes without loopback).
if [ "${SERVE_SMOKE:-1}" != "0" ]; then
  SERVE_LOG=$(mktemp)
  target/release/nlp-dse serve --addr 127.0.0.1:0 --threads 2 --jobs 1 2>"$SERVE_LOG" &
  SERVE_PID=$!
  PORT=""
  for _ in $(seq 1 100); do
    PORT=$(sed -n 's/.*listening on 127\.0\.0\.1:\([0-9]*\).*/\1/p' "$SERVE_LOG" | head -n1)
    [ -n "$PORT" ] && break
    sleep 0.1
  done
  if [ -z "$PORT" ]; then
    echo "ci: serve daemon never reported its port:" >&2
    cat "$SERVE_LOG" >&2
    kill "$SERVE_PID" 2>/dev/null || true
    exit 1
  fi
  serve_request() {  # one request line -> the terminal result/error line
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf '%s\n' "$1" >&3
    grep -m1 -E '"event":"(result|error)"' <&3
    exec 3>&- 3<&-
  }
  REQ='{"op":"solve","kernel":"gemm","size":"S","cap":16}'
  R1=$(serve_request "$REQ")
  R2=$(serve_request "$REQ")
  R3=$(serve_request '{"op":"stats"}')
  echo "$R1" | grep -q '"cache":"miss"' || { echo "ci: first solve was not a cache miss: $R1" >&2; exit 1; }
  echo "$R2" | grep -q '"cache":"hit"'  || { echo "ci: repeated solve was not a cache hit: $R2" >&2; exit 1; }
  echo "$R3" | grep -q '"hits":1'       || { echo "ci: stats did not count the hit: $R3" >&2; exit 1; }
  kill -TERM "$SERVE_PID"
  wait "$SERVE_PID"  # non-zero exit (unclean shutdown) fails ci via set -e
  rm -f "$SERVE_LOG"
  echo "    serve smoke passed (port $PORT, cache hit observed, clean SIGTERM exit)"
fi

echo "==> system smoke (SYSTEM_SMOKE=1 — two-kernel system mode: CLI, then serve miss->hit)"
# End-to-end check of the multi-kernel campaign: the CLI `system`
# command must print a feasible allocation for two small kernels, and
# the daemon's `system` op must compute once (miss) and replay the
# second identical request bit-identically (hit). Same /dev/tcp
# transport as the serve smoke. Skip with SYSTEM_SMOKE=0.
if [ "${SYSTEM_SMOKE:-1}" != "0" ]; then
  SYS_OUT=$(target/release/nlp-dse system --kernels gemm,bicg --size S --cap 16 --epsilon 0.05 --max-points 4)
  echo "$SYS_OUT" | grep -q 'system allocation:' \
    || { echo "ci: CLI system mode printed no allocation verdict:" >&2; echo "$SYS_OUT" >&2; exit 1; }
  echo "$SYS_OUT" | grep -q 'GF/s total' \
    || { echo "ci: CLI system allocation was not feasible on u200:" >&2; echo "$SYS_OUT" >&2; exit 1; }
  SERVE_LOG=$(mktemp)
  target/release/nlp-dse serve --addr 127.0.0.1:0 --threads 2 --jobs 1 2>"$SERVE_LOG" &
  SERVE_PID=$!
  PORT=""
  for _ in $(seq 1 100); do
    PORT=$(sed -n 's/.*listening on 127\.0\.0\.1:\([0-9]*\).*/\1/p' "$SERVE_LOG" | head -n1)
    [ -n "$PORT" ] && break
    sleep 0.1
  done
  if [ -z "$PORT" ]; then
    echo "ci: serve daemon never reported its port (system smoke):" >&2
    cat "$SERVE_LOG" >&2
    kill "$SERVE_PID" 2>/dev/null || true
    exit 1
  fi
  serve_request() {  # one request line -> the terminal result/error line
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf '%s\n' "$1" >&3
    grep -m1 -E '"event":"(result|error)"' <&3
    exec 3>&- 3<&-
  }
  SREQ='{"op":"system","kernels":["gemm","bicg"],"size":"S","cap":16,"epsilon":0.05,"max_points":4,"jobs":1}'
  S1=$(serve_request "$SREQ")
  S2=$(serve_request "$SREQ")
  echo "$S1" | grep -q '"cache":"miss"' || { echo "ci: first system op was not a cache miss: $S1" >&2; exit 1; }
  echo "$S2" | grep -q '"cache":"hit"'  || { echo "ci: repeated system op was not a cache hit: $S2" >&2; exit 1; }
  # the replayed payload must be byte-identical modulo the cache tag
  [ "${S1//\"cache\":\"miss\"/}" = "${S2//\"cache\":\"hit\"/}" ] \
    || { echo "ci: system replay differed from the original payload" >&2; exit 1; }
  echo "$S1" | grep -q '"feasible":true' \
    || { echo "ci: serve system allocation was not feasible: $S1" >&2; exit 1; }
  kill -TERM "$SERVE_PID"
  wait "$SERVE_PID"
  rm -f "$SERVE_LOG"
  echo "    system smoke passed (CLI verdict + serve miss->hit replay, port $PORT)"
fi

echo "==> surrogate smoke (SURROGATE_SMOKE=1 — train an artifact, rank-cut a DSE with it)"
# End-to-end check of the learned-surrogate path through the release
# binary: `train` must fit and persist a versioned artifact and report
# its held-out rank correlation, and `dse --engine surrogate` must load
# that artifact and finish with an exact-scored best design. Skip with
# SURROGATE_SMOKE=0.
if [ "${SURROGATE_SMOKE:-1}" != "0" ]; then
  SUR_MODEL=$(mktemp --suffix=.json)
  TRAIN_OUT=$(target/release/nlp-dse train --model-file "$SUR_MODEL" --kernels 3 --designs 8)
  echo "$TRAIN_OUT" | grep -q 'holdout spearman' \
    || { echo "ci: train printed no holdout rank correlation:" >&2; echo "$TRAIN_OUT" >&2; exit 1; }
  grep -q '"kind": *"nlp-dse-surrogate-ridge"' "$SUR_MODEL" \
    || { echo "ci: train did not persist a surrogate artifact at $SUR_MODEL" >&2; exit 1; }
  SUR_OUT=$(target/release/nlp-dse dse --kernel mvt --size S --engine surrogate \
    --model-file "$SUR_MODEL" --verify-fraction 0.5 --jobs 2)
  echo "$SUR_OUT" | grep -q 'engine `surrogate`' \
    || { echo "ci: surrogate DSE named the wrong engine:" >&2; echo "$SUR_OUT" >&2; exit 1; }
  echo "$SUR_OUT" | grep -q 'best design' \
    || { echo "ci: surrogate DSE reported no best design:" >&2; echo "$SUR_OUT" >&2; exit 1; }
  rm -f "$SUR_MODEL"
  echo "    surrogate smoke passed (artifact trained, rank-cut DSE found a best design)"
fi

echo "==> bench smoke (smallest sizes, BENCH_MS=25 — benches can't rot)"
# Stash the committed BENCH_solver.json before the fresh run overwrites
# it: bench_nlp_solver compares its fresh configs/s per tag against the
# stash and exits non-zero on a drop past BENCH_TOLERANCE percent
# (default 20 — generous because smoke runs on shared CI hardware).
# First run on a machine with no committed baseline self-blesses.
BENCH_STASH=""
if [ -f BENCH_solver.json ]; then
  BENCH_STASH=$(mktemp)
  cp BENCH_solver.json "$BENCH_STASH"
fi
rm -f BENCH_solver.json  # a stale file must not satisfy the emission check
for bench in bench_tables bench_model_eval bench_nlp_solver bench_space_enum bench_runtime_batch bench_codegen bench_serve bench_transform bench_system bench_surrogate; do
  if [ "$bench" = bench_nlp_solver ] && [ -n "$BENCH_STASH" ]; then
    BENCH_SMOKE=1 BENCH_MS=25 BENCH_BASELINE="$BENCH_STASH" \
      BENCH_TOLERANCE="${BENCH_TOLERANCE:-20}" cargo bench --bench "$bench"
  else
    BENCH_SMOKE=1 BENCH_MS=25 cargo bench --bench "$bench"
  fi
done
if [ -n "$BENCH_STASH" ]; then
  rm -f "$BENCH_STASH"
fi
if [ ! -f BENCH_solver.json ]; then
  echo "ci: bench_nlp_solver did not emit BENCH_solver.json at the repo root" >&2
  exit 1
fi
echo "    BENCH_solver.json emitted (regression gate ran against the committed baseline when present)"

echo "ci: all checks passed"
