//! Kernel frontend: bring your own loop nest as `.knl` text, or let the
//! seeded generator invent one — then run the full pragma-insertion
//! stack on it, exactly as on the PolyBench corpus.
//!
//! ```bash
//! cargo run --release --example kernel_frontend
//! ```
//!
//! Three legs:
//! 1. parse a hand-written `.knl` kernel and explore it;
//! 2. show the span-anchored diagnostics a malformed kernel produces;
//! 3. generate a random-but-always-regular kernel from a seed, round-trip
//!    it through pretty-print → parse, and explore that too.

use nlp_dse::engine::{Evaluator, Explorer};
use nlp_dse::frontend::{self, GenConfig};

// A blocked vector-scale + dot-product pair, written by hand. Any
// regular loop nest works: affine (triangular) bounds, typed arrays
// with transfer directions, statements with affine accesses + op
// multisets.
const MY_KERNEL: &str = r#"
kernel "scale-dot" f32

array x[256] inout
array y[256] in
array dot[1] inout

for i in 0 .. 256 {
  stmt scale writes x[i] reads x[i] ops mul;
}
for j in 0 .. 256 {
  stmt acc writes dot[0] reads dot[0], x[j], y[j] ops mul, add;
}
"#;

fn main() -> anyhow::Result<()> {
    // --- 1. text -> Kernel -> exploration -------------------------------
    let kernel = frontend::parse_kernel(MY_KERNEL, "scale-dot.knl")?;
    println!(
        "parsed `{}`: {} loops, {} statements (summary AST {})",
        kernel.name,
        kernel.n_loops(),
        kernel.n_stmts(),
        kernel.summary_ast()
    );
    let outcome = Explorer::custom(kernel.clone())
        .evaluator(Evaluator::rust())
        .run()?;
    println!("{}", outcome.render(&kernel));

    // --- 2. diagnostics --------------------------------------------------
    let broken = MY_KERNEL.replace("x[j]", "x[k]");
    let err = frontend::parse_kernel(&broken, "scale-dot.knl").unwrap_err();
    println!("a malformed kernel reports, with source spans:\n{err}\n");

    // --- 3. seeded generation + round-trip -------------------------------
    let cfg = GenConfig::sampled(0xC0FFEE);
    let generated = frontend::generate(&cfg);
    let text = frontend::pretty::print(&generated);
    println!("generated from seed {:#x}:\n{text}", cfg.seed);
    let reparsed = frontend::parse_kernel(&text, "<roundtrip>")?;
    assert_eq!(
        generated.structural_diff(&reparsed),
        None,
        "pretty-print -> parse must round-trip"
    );
    let outcome = Explorer::custom(generated.clone())
        .evaluator(Evaluator::rust())
        .run()?;
    println!("round-trip holds; exploring the generated kernel:");
    println!("{}", outcome.render(&generated));
    Ok(())
}
