//! Bring-your-own-kernel: define a custom affine kernel with the builder
//! API and let the `Explorer` facade insert pragmas for it.
//!
//! ```bash
//! cargo run --release --example pragma_insertion
//! ```
//!
//! The kernel is a blocked dot-product chain (`y[i] = Σ_j A[i][j]·x[j]`,
//! then `z = Σ y[i]`) — not part of the PolyBench suite, demonstrating
//! that the whole pipeline (analysis → NLP → Merlin/HLS verification)
//! works on user programs: `Explorer::custom` accepts any `Kernel` and
//! every registered engine runs on it unchanged.

use nlp_dse::engine::{Evaluator, Explorer};
use nlp_dse::ir::{ArrayDir, DType, KernelBuilder, OpKind};

fn main() {
    // --- define the kernel ---------------------------------------------------
    let n: i64 = 1024;
    let mut kb = KernelBuilder::new("dotchain", DType::F32);
    let a = kb.array("A", &[n as u64, n as u64], ArrayDir::In);
    let x = kb.array("x", &[n as u64], ArrayDir::In);
    let y = kb.array("y", &[n as u64], ArrayDir::Temp);
    let z = kb.array("z", &[1], ArrayDir::Out);

    kb.for_const("i", 0, n, |kb, i| {
        kb.stmt("S0", vec![kb.at(y, &[kb.v(i)])], vec![], &[]);
        kb.for_const("j", 0, n, |kb, j| {
            // y[i] += A[i][j] * x[j]
            kb.stmt(
                "S1",
                vec![kb.at(y, &[kb.v(i)])],
                vec![
                    kb.at(y, &[kb.v(i)]),
                    kb.at(a, &[kb.v(i), kb.v(j)]),
                    kb.at(x, &[kb.v(j)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
    });
    kb.for_const("i2", 0, n, |kb, i2| {
        // z += y[i]
        kb.stmt(
            "S2",
            vec![kb.at(z, &[kb.c(0)])],
            vec![kb.at(z, &[kb.c(0)]), kb.at(y, &[kb.v(i2)])],
            &[(OpKind::Add, 1)],
        );
    });

    // --- hand the kernel to the facade ---------------------------------------
    let explorer = Explorer::custom(kb.finish())
        .evaluator(Evaluator::rust())
        .engine("nlpdse")
        .expect("nlpdse is a registered engine");
    let kernel = explorer.kernel_ref();
    let analysis = explorer.analysis();
    println!(
        "kernel {}: {} loops, {} deps; reduction loops: {:?}",
        kernel.name,
        kernel.n_loops(),
        analysis.deps.nd(),
        (0..kernel.n_loops())
            .filter(|&i| analysis.deps.per_loop[i].reduction)
            .collect::<Vec<_>>()
    );

    // --- run the full DSE (Algorithm 1) --------------------------------------
    let out = explorer.run().expect("exploration succeeds");
    println!(
        "\nNLP-DSE: best {:.2} GF/s (first synthesizable {:.2}), {:.0} simulated minutes, \
         {} designs explored",
        out.best_gflops, out.first_synth_gflops, out.wall_minutes, out.synth_calls
    );
    let (best, cycles) = out.best.expect("found a design");
    println!("best design ({cycles:.0} cycles):\n{}", best.render(kernel));
}
