//! Multi-kernel DSE campaign across the coordinator's thread pool,
//! emitting the Table-5-style comparison and a JSON dump.
//!
//! ```bash
//! cargo run --release --example dse_campaign -- [quick|paper|harp] [engines]
//! ```
//!
//! The optional second argument is a comma-separated list of registry
//! engine names (e.g. `nlpdse,random`); the coordinator schedules one
//! `Box<dyn Engine>` job per (kernel, engine) pair. Third-party
//! engines join the same way through
//! `coordinator::run_campaign_with(&my_registry, &cfg)` — no
//! coordinator edit.

use nlp_dse::cli::campaign_json;
use nlp_dse::coordinator::{run_campaign, CampaignConfig};
use nlp_dse::engine::Registry;
use nlp_dse::report;

fn main() {
    let scope = std::env::args().nth(1).unwrap_or_else(|| "quick".into());
    let mut cfg = match scope.as_str() {
        "paper" => CampaignConfig::paper_autodse(),
        "harp" => CampaignConfig::paper_harp(),
        _ => CampaignConfig::quick(),
    };
    if let Some(list) = std::env::args().nth(2) {
        let reg = Registry::builtin();
        cfg.engines = list.split(',').map(|s| s.trim().to_string()).collect();
        for e in &cfg.engines {
            assert!(
                reg.contains(e),
                "unknown engine `{e}` (registered: {})",
                reg.names().join(", ")
            );
        }
    }
    eprintln!(
        "[campaign] {} kernel instances × engines [{}] on {} threads",
        cfg.kernels.len(),
        cfg.engines.join(", "),
        cfg.threads
    );
    let t0 = std::time::Instant::now();
    let result = run_campaign(&cfg);
    eprintln!("[campaign] finished in {:.1}s", t0.elapsed().as_secs_f64());

    println!("{}", report::table5(&result).render());
    if scope == "harp" {
        println!("{}", report::table9(&result).render());
    }

    let json = campaign_json(&result);
    let path = format!("campaign_{scope}.json");
    std::fs::write(&path, json.to_string_pretty()).expect("write json");
    eprintln!("[campaign] wrote {path}");
}
