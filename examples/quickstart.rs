//! Quickstart: automatically insert Merlin pragmas into a PolyBench kernel.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! The **front door** is the `Explorer` session facade: pick a kernel,
//! pick an engine from the registry (`nlpdse`, `autodse`, `harp`,
//! `random`, …), and run — the facade owns kernel construction, exact
//! analysis, evaluator selection (AOT XLA artifact when available,
//! in-process Rust reference otherwise), and the simulated Merlin/Vitis
//! oracle. Every engine returns the same normalized `Exploration`.
//!
//! The low-level modules (`nlp`, `hls`, `poly`, …) remain public as the
//! **escape hatch**; the second half of this example drops down to them
//! for a single NLP solve against the session's own substrate.

use nlp_dse::benchmarks::Size;
use nlp_dse::engine::{Evaluator, Explorer};
use nlp_dse::hls::{Device, HlsOracle};
use nlp_dse::nlp::{self, NlpProblem, RustFeatureEvaluator};

fn main() {
    // --- front door: one chained call ----------------------------------
    let explorer = Explorer::kernel("gemm", Size::Medium)
        .expect("gemm is a registered benchmark")
        .device(Device::u200())
        .evaluator(Evaluator::auto())
        .engine("nlpdse")
        .expect("nlpdse is a registered engine");

    let kernel = explorer.kernel_ref();
    let analysis = explorer.analysis();
    println!("kernel: {}  (summary AST: {})", kernel.name, kernel.summary_ast());
    println!(
        "{} loops, {} dependences, {:.0} kB footprint, {:.2e} flops\n",
        kernel.n_loops(),
        analysis.deps.nd(),
        analysis.total_footprint as f64 / 1024.0,
        analysis.total_flops
    );

    let outcome = explorer.run().expect("exploration succeeds");
    println!("{}", outcome.render(kernel));

    // --- escape hatch: one NLP solve on the same substrate --------------
    let device = explorer.device_ref();
    let problem = NlpProblem::new(kernel, analysis, device, 512, false);
    let solution = nlp::solve(&problem, 30.0, 1, &RustFeatureEvaluator);
    let (design, bound) = solution.best().expect("feasible design").clone();
    println!(
        "\nsingle NLP solve at cap=512 (lower bound {:.0} cycles = {:.2} GF/s bound), \
         solved in {:.0} ms:\n{}",
        bound,
        analysis.gflops(bound, device.freq_hz),
        solution.solve_time_s * 1e3,
        design.render(kernel)
    );

    // verify that sub-space optimum with the simulated Merlin + Vitis
    // toolchain — the same oracle the engines used above
    let oracle = HlsOracle::new(device.clone());
    let report = oracle.synth(kernel, analysis, &design);
    println!(
        "HLS report: {:.0} cycles ({:.2} GF/s), DSP {}, BRAM {}, II {:.0}, synth {:.0} min, \
         pragmas applied: {}",
        report.cycles,
        report.gflops(analysis, device),
        report.dsp,
        report.bram18k,
        report.achieved_ii,
        report.synth_minutes,
        report.pragmas_applied
    );
    assert!(
        report.flattened || report.cycles >= bound * 0.999,
        "lower-bound property violated"
    );
    println!("\nlower-bound property holds: measured >= predicted bound");
}
