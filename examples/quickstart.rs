//! Quickstart: automatically insert Merlin pragmas into a PolyBench kernel.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds `gemm` (medium size), formulates the NLP, solves it, prints the
//! chosen pragma configuration with its latency lower bound, and verifies
//! the design against the simulated Merlin+Vitis toolchain.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::{Device, HlsOracle};
use nlp_dse::ir::DType;
use nlp_dse::nlp::{self, NlpProblem, RustFeatureEvaluator};
use nlp_dse::poly::Analysis;

fn main() {
    // 1. the input program: a regular loop-based affine kernel
    let kernel = benchmarks::build("gemm", Size::Medium, DType::F32).unwrap();
    println!("kernel: {}  (summary AST: {})\n", kernel.name, kernel.summary_ast());

    // 2. exact static analysis: trip counts, dependences, footprints
    let analysis = Analysis::new(&kernel);
    println!(
        "{} loops, {} dependences, {:.0} kB footprint, {:.2e} flops\n",
        kernel.n_loops(),
        analysis.deps.nd(),
        analysis.total_footprint as f64 / 1024.0,
        analysis.total_flops
    );

    // 3. formulate + solve the NLP (pragmas are the unknowns)
    let device = Device::u200();
    let problem = NlpProblem::new(&kernel, &analysis, &device, 512, false);
    let solution = nlp::solve(&problem, 30.0, 1, &RustFeatureEvaluator);
    let (design, bound) = solution.best().expect("feasible design").clone();
    println!(
        "NLP optimum (lower bound {:.0} cycles = {:.2} GF/s bound), solved in {:.0} ms:\n{}",
        bound,
        analysis.gflops(bound, device.freq_hz),
        solution.solve_time_s * 1e3,
        design.render(&kernel)
    );

    // 4. verify with the (simulated) Merlin + Vitis toolchain
    let oracle = HlsOracle::new(device.clone());
    let report = oracle.synth(&kernel, &analysis, &design);
    println!(
        "HLS report: {:.0} cycles ({:.2} GF/s), DSP {}, BRAM {}, II {:.0}, synth {:.0} min, \
         pragmas applied: {}",
        report.cycles,
        report.gflops(&analysis, &device),
        report.dsp,
        report.bram18k,
        report.achieved_ii,
        report.synth_minutes,
        report.pragmas_applied
    );
    assert!(
        report.flattened || report.cycles >= bound * 0.999,
        "lower-bound property violated"
    );
    println!("\nlower-bound property holds: measured >= predicted bound");
}
