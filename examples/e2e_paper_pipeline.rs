//! **End-to-end driver** — proves all three layers compose on the paper's
//! headline workload:
//!
//!   L1/L2 (Pallas/JAX, AOT-compiled to `artifacts/lat_bound.hlo.txt`)
//!   → runtime (PJRT CPU client executing the artifact from Rust)
//!   → L3 (NLP solver + Algorithm-1 DSE against the simulated
//!     Merlin/Vitis toolchain)
//!
//! Workload: the motivation trio of Tables 1–3 (2mm-M, gemm-M,
//! gramschmidt-L) with both NLP-DSE and AutoDSE, reporting the paper's
//! headline metric — throughput (GF/s) and DSE time (min) improvements.
//! The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_paper_pipeline
//! ```

use nlp_dse::baselines::{run_autodse, AutoDseConfig};
use nlp_dse::benchmarks::{self, Size};
use nlp_dse::dse::{run_nlp_dse, DseConfig};
use nlp_dse::hls::{Device, HlsOracle};
use nlp_dse::ir::DType;
use nlp_dse::nlp::BatchEvaluator;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;
use nlp_dse::runtime::{default_artifact_dir, XlaEvaluator};
use nlp_dse::util::table::{f2, i0, ratio, TextTable};

fn main() {
    // --- layer check: the AOT artifact must load and execute ----------------
    let eval = match XlaEvaluator::load(&default_artifact_dir()) {
        Ok(e) => {
            println!(
                "[e2e] XLA artifact loaded (batch={}) — python is NOT on the request path",
                e.batch
            );
            e
        }
        Err(e) => {
            eprintln!("[e2e] artifacts missing ({e:#}); run `make artifacts` first");
            std::process::exit(2);
        }
    };

    let trio = [
        ("2mm", Size::Medium),
        ("gemm", Size::Medium),
        ("gramschmidt", Size::Large),
    ];
    let device = Device::u200();
    let mut table = TextTable::new(
        "E2E — NLP-DSE (through the XLA artifact) vs AutoDSE",
        &[
            "kernel", "orig GF/s", "NLP-DSE GF/s", "T(min)", "XLA execs", "AutoDSE GF/s",
            "T(min)", "QoR imp", "time imp",
        ],
    );

    for (name, size) in trio {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let oracle = HlsOracle::new(device.clone());
        let orig = oracle.synth(&k, &a, &Design::empty(&k)).gflops(&a, &device);

        let execs_before = eval.executions.get();
        let n = run_nlp_dse(&k, &a, &device, &DseConfig::default(), &eval);
        let execs = eval.executions.get() - execs_before;
        assert!(execs > 0, "the XLA artifact must be exercised");

        let auto = run_autodse(&k, &a, &device, &AutoDseConfig::default());

        table.row(vec![
            format!("{name}-{}", size.tag()),
            f2(orig),
            f2(n.best_gflops),
            i0(n.dse_minutes),
            execs.to_string(),
            f2(auto.best_gflops),
            i0(auto.dse_minutes),
            ratio(n.best_gflops / auto.best_gflops.max(1e-9)),
            ratio(auto.dse_minutes / n.dse_minutes.max(1e-9)),
        ]);
        // the paper's core claims, as assertions:
        assert!(
            n.best_gflops > orig * 2.0,
            "{name}: NLP-DSE must beat the pragma-free design"
        );
        assert!(
            n.dse_minutes < auto.dse_minutes,
            "{name}: NLP-DSE must be faster than AutoDSE"
        );
    }
    println!("\n{}", table.render());
    // sanity line consumed by EXPERIMENTS.md
    let _ = eval;
    println!("[e2e] all layer-composition checks passed");
}
