//! **End-to-end driver** — proves all three layers compose on the paper's
//! headline workload:
//!
//!   L1/L2 (Pallas/JAX, AOT-compiled to `artifacts/lat_bound.hlo.txt`)
//!   → runtime (PJRT CPU client executing the artifact from Rust)
//!   → L3 (the `Explorer` facade running the `nlpdse` and `autodse`
//!     engines against the simulated Merlin/Vitis toolchain)
//!
//! Workload: the motivation trio of Tables 1–3 (2mm-M, gemm-M,
//! gramschmidt-L) with both NLP-DSE and AutoDSE, reporting the paper's
//! headline metric — throughput (GF/s) and DSE time (min) improvements.
//! The run is recorded in EXPERIMENTS.md.
//!
//! The XLA evaluator is injected through `Evaluator::custom`, keeping a
//! handle on the instrumented evaluator so the example can assert the
//! artifact was actually exercised on the DSE hot path.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_paper_pipeline
//! ```
//! (requires a build with `--features xla`)

use nlp_dse::benchmarks::Size;
use nlp_dse::engine::{Evaluator, Explorer};
use nlp_dse::hls::{Device, HlsOracle};
use nlp_dse::pragma::Design;
use nlp_dse::runtime::{default_artifact_dir, XlaEvaluator};
use nlp_dse::util::table::{f2, i0, ratio, TextTable};
use std::sync::Arc;

fn main() {
    // --- layer check: the AOT artifact must load and execute ----------------
    let eval = match XlaEvaluator::load(&default_artifact_dir()) {
        Ok(e) => {
            println!(
                "[e2e] XLA artifact loaded (batch={}) — python is NOT on the request path",
                e.batch
            );
            Arc::new(e)
        }
        Err(e) => {
            eprintln!("[e2e] artifacts missing ({e:#}); run `make artifacts` first");
            std::process::exit(2);
        }
    };

    let trio = [
        ("2mm", Size::Medium),
        ("gemm", Size::Medium),
        ("gramschmidt", Size::Large),
    ];
    let device = Device::u200();
    let mut table = TextTable::new(
        "E2E — NLP-DSE (through the XLA artifact) vs AutoDSE",
        &[
            "kernel", "orig GF/s", "NLP-DSE GF/s", "T(min)", "XLA execs", "AutoDSE GF/s",
            "T(min)", "QoR imp", "time imp",
        ],
    );

    for (name, size) in trio {
        let explorer = Explorer::kernel(name, size)
            .expect("registered benchmark")
            .device(device.clone())
            .evaluator(Evaluator::custom(eval.clone()));
        let k = explorer.kernel_ref();
        let a = explorer.analysis();
        let oracle = HlsOracle::new(device.clone());
        let orig = oracle.synth(k, a, &Design::empty(k)).gflops(a, &device);

        let execs_before = eval.executions();
        let n = explorer.run_engine("nlpdse").expect("nlpdse engine");
        let execs = eval.executions() - execs_before;
        assert!(execs > 0, "the XLA artifact must be exercised");

        let auto = explorer.run_engine("autodse").expect("autodse engine");

        table.row(vec![
            format!("{name}-{}", size.tag()),
            f2(orig),
            f2(n.best_gflops),
            i0(n.wall_minutes),
            execs.to_string(),
            f2(auto.best_gflops),
            i0(auto.wall_minutes),
            ratio(n.best_gflops / auto.best_gflops.max(1e-9)),
            ratio(auto.wall_minutes / n.wall_minutes.max(1e-9)),
        ]);
        // the paper's core claims, as assertions:
        assert!(
            n.best_gflops > orig * 2.0,
            "{name}: NLP-DSE must beat the pragma-free design"
        );
        assert!(
            n.wall_minutes < auto.wall_minutes,
            "{name}: NLP-DSE must be faster than AutoDSE"
        );
    }
    println!("\n{}", table.render());
    // sanity line consumed by EXPERIMENTS.md
    println!("[e2e] all layer-composition checks passed");
}
